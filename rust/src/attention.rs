//! Native CPU GQA attention engine — the simulator substrate for the
//! paper's accuracy and kernel-shape experiments.
//!
//! Mirrors the semantics of the Pallas kernels (python/compile/kernels/):
//! dense decode/prefill, post-softmax pooled scores (GQA pooling in
//! decode, Q-tile pooling in prefill), sparse attention over explicit
//! per-KV-head index sets with causal clamping, and the multi-pass anchor
//! pipeline cost structure.  A [`CostTracker`] accounts key/value reads and
//! score FLOPs so experiments can report work ratios alongside wall-clock.

use crate::config::KvDtype;
use crate::simd::{self, SimdLevel};
use crate::tensor::{dequantize_q4, dequantize_q8, f16_to_f32, f32_to_f16, quantize_q4, quantize_q8};
use crate::tilestore::{SharedTileStore, TierParams, TierStats, TileKey, TileStoreError};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Per-layer KV cache: contiguous `[n_kv, cap, d]` storage plus per-page
/// min/max key summaries (used by the Quest baseline).
///
/// Four storage modes ([`KvDtype`]):
///
/// * **F32** — plain f32 buffers, the exact baseline.
/// * **F16** — completed tiles stored as IEEE binary16 bit patterns
///   (software-converted, f32 accumulation in every kernel); no per-tile
///   params — the conversion is a pure per-element rounding.
/// * **Int8** — completed quantization tiles (one tile = `page_size`
///   positions, aligned with the paged-KV block size) are stored as int8
///   with a per-tile, per-head affine `(scale, zero)` pair for K and for
///   V.
/// * **Int4** — completed tiles as packed 4-bit codes (two per byte) with
///   the same per-tile, per-head affine params as Int8; requires an even
///   head dim.
///
/// Every compressed mode shares the staging-tile architecture: the
/// current partially-filled tail tile lives in a small f32 staging
/// buffer (`[n_kv, page_size, d]`) until it completes, then is converted
/// once with its final content and never touched again — which is what
/// lets copy-on-write forks share completed blocks byte-for-byte without
/// re-converting.
///
/// Kernels never read raw storage directly: [`KvCache::dot_key`] scores
/// fused over stored rows (no dequantized materialization) and
/// [`KvCache::add_val`] converts value rows on attend.  All kernel inner
/// loops dispatch through [`crate::simd`] at the level stamped once at
/// construction (`simd` field) — never re-probed per tile.
#[derive(Clone)]
pub struct KvCache {
    pub n_kv: usize,
    pub d: usize,
    pub cap: usize,
    pub len: usize,
    dtype: KvDtype,
    /// F32 mode: full `[n_kv, cap, d]` K/V storage.  Compressed modes:
    /// the f32 staging tail, `[n_kv, page_size, d]` (current partial
    /// tile only).
    k: Vec<f32>,
    v: Vec<f32>,
    /// Int8 mode: quantized completed tiles, `[n_kv, cap, d]`.
    kq: Vec<i8>,
    vq: Vec<i8>,
    /// F16 mode: completed tiles as binary16 bits, `[n_kv, cap, d]`.
    kh: Vec<u16>,
    vh: Vec<u16>,
    /// Int4 mode: completed tiles as packed nibbles (low nibble = even
    /// element), `[n_kv, cap, d/2]`.
    k4: Vec<u8>,
    v4: Vec<u8>,
    /// Int8/Int4 modes: per `(head, tile)` affine params, `[n_kv, n_tiles]`.
    kscale: Vec<f32>,
    kzero: Vec<f32>,
    vscale: Vec<f32>,
    vzero: Vec<f32>,
    /// Vector level every kernel on this cache dispatches through —
    /// stamped from [`crate::simd::detect`] at construction; overridable
    /// only via [`KvCache::set_simd_level`] (benches / property tests).
    simd: SimdLevel,
    /// page summaries: for each kv head and page, elementwise min and max
    /// of the keys in the page: `[n_kv, n_pages, 2, d]`.
    page_size: usize,
    pages: Vec<f32>,
    /// Tiered mode (`docs/kv-tiers.md`): hot/warm/cold residency state
    /// for completed tiles.  `None` = every tile resident (flat modes).
    /// When tiered, `kq`/`vq` become a slot *arena* (`[hot_slots, n_kv,
    /// page_size, d]`) instead of the full `[n_kv, cap, d]` planes;
    /// scales/zeros and page summaries stay fully resident (tiny).
    tier: Option<Box<TierState>>,
}

/// Sentinel for "no arena slot" / "free slot".
const NO_SLOT: u32 = u32::MAX;

/// Residency tier of one completed tile (diagnostics/tests; the staging
/// tail is always resident and reports `None`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileTier {
    /// Exact int8 codes resident in the hot arena — the only tier the
    /// compute kernels ever read.
    Hot,
    /// Demoted: exact payload spilled to the [`crate::tilestore`] store,
    /// plus a packed-int4 RAM shadow (approximate, diagnostics only).
    Warm,
    /// Demoted with the int4 shadow dropped: spill record only.
    Cold,
}

/// Warm shadow of one demoted tile: packed int4 codes for K and V plus
/// per-head affine params (`[ks, kz, vs, vz]` per head).
struct WarmTile {
    k4: Vec<u8>,
    v4: Vec<u8>,
    affine: Vec<f32>,
}

/// Tier bookkeeping for one tiered [`KvCache`].  All per-tile vectors
/// are indexed by completed-tile id and grow as tiles complete.
struct TierState {
    cfg: TierParams,
    store: SharedTileStore,
    layer: u32,
    /// Owner id new spill records are keyed under; refreshed on clone
    /// and truncate so post-divergence tiles never collide with records
    /// an ancestor sequence wrote (see [`TileKey`]).
    self_owner: u32,
    /// Per tile: the owner its spill record is keyed by (stamped at
    /// completion; inherited unchanged across forks).
    tile_owner: Vec<u32>,
    /// Per tile: arena slot when hot, [`NO_SLOT`] otherwise.
    slot_of: Vec<u32>,
    /// Per slot: resident tile, [`NO_SLOT`] when free.
    tile_of: Vec<u32>,
    free_slots: Vec<u32>,
    hot_count: usize,
    /// LRU stamps (logical clock) + lazy min-heap of demotion candidates
    /// (stale entries are skipped at pop; ties cannot happen — stamps
    /// are unique).
    stamp: Vec<u64>,
    clock: u64,
    lru: BinaryHeap<Reverse<(u64, u32)>>,
    /// Per tile: epoch of the last `ensure_hot_*` call that needed it —
    /// tiles needed in the current epoch are never demotion victims.
    visited: Vec<u64>,
    epoch: u64,
    /// Warm shadows + demotion order for warm→cold aging.
    warm: Vec<Option<Box<WarmTile>>>,
    warm_order: VecDeque<u32>,
    warm_count: usize,
    stats: TierStats,
    /// Reusable spill-payload / dequantize scratch.
    payload: Vec<u8>,
    scratch: Vec<f32>,
}

impl TierState {
    /// Bump the LRU stamp of `tile` (most-recently-needed).
    fn touch(&mut self, tile: usize) {
        self.clock += 1;
        self.stamp[tile] = self.clock;
        self.lru.push(Reverse((self.clock, tile as u32)));
        // lazy heap: compact when stale entries dominate
        if self.lru.len() > 4 * self.stamp.len() + 64 {
            let stamp = &self.stamp;
            let slot_of = &self.slot_of;
            let mut fresh = BinaryHeap::with_capacity(self.hot_count + 1);
            for (t, &s) in stamp.iter().enumerate() {
                if slot_of[t] != NO_SLOT {
                    fresh.push(Reverse((s, t as u32)));
                }
            }
            self.lru = fresh;
        }
    }

    /// Grow the per-tile bookkeeping to cover `tile`.
    fn grow_to(&mut self, tile: usize) {
        if self.slot_of.len() <= tile {
            self.slot_of.resize(tile + 1, NO_SLOT);
            self.tile_owner.resize(tile + 1, 0);
            self.stamp.resize(tile + 1, 0);
            self.visited.resize(tile + 1, 0);
            self.warm.resize_with(tile + 1, || None);
        }
    }
}

impl Clone for TierState {
    fn clone(&self) -> Self {
        // A cloned cache (prefix fork / snapshot) diverges from here on:
        // refresh the owner so tiles completed AFTER the clone spill
        // under fresh keys, while inherited tiles keep `tile_owner` and
        // share their ancestor's immutable records.
        let self_owner = match self.store.lock() {
            Ok(mut s) => s.alloc_owner(),
            // a poisoned store mutex means a worker already panicked
            // mid-spill; this cache is unusable
            Err(_) => panic!("tile store mutex poisoned during cache clone"),
        };
        Self {
            cfg: self.cfg,
            store: self.store.clone(),
            layer: self.layer,
            self_owner,
            tile_owner: self.tile_owner.clone(),
            slot_of: self.slot_of.clone(),
            tile_of: self.tile_of.clone(),
            free_slots: self.free_slots.clone(),
            hot_count: self.hot_count,
            stamp: self.stamp.clone(),
            clock: self.clock,
            lru: self.lru.clone(),
            visited: self.visited.clone(),
            epoch: self.epoch,
            warm: self
                .warm
                .iter()
                .map(|w| {
                    w.as_ref().map(|b| {
                        Box::new(WarmTile {
                            k4: b.k4.clone(),
                            v4: b.v4.clone(),
                            affine: b.affine.clone(),
                        })
                    })
                })
                .collect(),
            warm_order: self.warm_order.clone(),
            warm_count: self.warm_count,
            // counters are per-cache telemetry, not state: a fork starts
            // its own tallies rather than double-reporting its parent's
            stats: TierStats::default(),
            payload: Vec::new(),
            scratch: Vec::new(),
        }
    }
}

impl KvCache {
    pub fn new(n_kv: usize, d: usize, cap: usize) -> Self {
        Self::with_page_size(n_kv, d, cap, 16)
    }

    pub fn with_page_size(n_kv: usize, d: usize, cap: usize, page_size: usize) -> Self {
        Self::with_opts(n_kv, d, cap, page_size, KvDtype::F32)
    }

    pub fn with_opts(n_kv: usize, d: usize, cap: usize, page_size: usize, dtype: KvDtype) -> Self {
        let n_pages = cap.div_ceil(page_size);
        let staging = n_kv * page_size * d;
        // per-mode plane sizes: (f32, int8, f16, packed-int4, affine params)
        let (f32_len, q_len, h_len, p_len, s_len) = match dtype {
            KvDtype::F32 => (n_kv * cap * d, 0, 0, 0, 0),
            KvDtype::F16 => (staging, 0, n_kv * cap * d, 0, 0),
            KvDtype::Int8 => (staging, n_kv * cap * d, 0, 0, n_kv * n_pages),
            KvDtype::Int4 => {
                assert!(d % 2 == 0, "Int4 KV needs an even head dim (nibble packing), got {d}");
                (staging, 0, 0, n_kv * cap * d / 2, n_kv * n_pages)
            }
        };
        Self {
            n_kv,
            d,
            cap,
            len: 0,
            dtype,
            k: vec![0.0; f32_len],
            v: vec![0.0; f32_len],
            kq: vec![0; q_len],
            vq: vec![0; q_len],
            kh: vec![0; h_len],
            vh: vec![0; h_len],
            k4: vec![0; p_len],
            v4: vec![0; p_len],
            kscale: vec![0.0; s_len],
            kzero: vec![0.0; s_len],
            vscale: vec![0.0; s_len],
            vzero: vec![0.0; s_len],
            simd: crate::simd::detect(),
            page_size,
            pages: vec![0.0; n_kv * n_pages * 2 * d],
            tier: None,
        }
    }

    /// Tiered int8 cache (`docs/kv-tiers.md`): completed tiles live in a
    /// hot slot arena bounded by `cfg.hot_tile_budget` and demote
    /// through warm (int4 shadow) to cold (spill record in `store`).
    /// `layer` keys this cache's spill records.  Requires an even head
    /// dim (int4 packing) and the usual Int8 tile geometry.
    pub fn with_tiers(
        n_kv: usize,
        d: usize,
        cap: usize,
        page_size: usize,
        layer: usize,
        cfg: TierParams,
        store: SharedTileStore,
    ) -> Self {
        assert!(d % 2 == 0, "tiered KV needs an even head dim (int4 packing), got {d}");
        let mut me = Self::with_opts(n_kv, d, cap, page_size, KvDtype::Int8);
        // the flat quantized planes become an on-demand slot arena
        me.kq = Vec::new();
        me.vq = Vec::new();
        let self_owner = match store.lock() {
            Ok(mut s) => s.alloc_owner(),
            // poisoned store mutex: a worker already panicked mid-spill;
            // construction cannot proceed
            Err(_) => panic!("tile store mutex poisoned during cache construction"),
        };
        me.tier = Some(Box::new(TierState {
            cfg,
            store,
            layer: layer as u32,
            self_owner,
            tile_owner: Vec::new(),
            slot_of: Vec::new(),
            tile_of: Vec::new(),
            free_slots: Vec::new(),
            hot_count: 0,
            stamp: Vec::new(),
            clock: 0,
            lru: BinaryHeap::new(),
            visited: Vec::new(),
            epoch: 0,
            warm: Vec::new(),
            warm_order: VecDeque::new(),
            warm_count: 0,
            stats: TierStats::default(),
            payload: Vec::new(),
            scratch: Vec::new(),
        }));
        me
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn n_pages(&self) -> usize {
        self.len.div_ceil(self.page_size)
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// True for the integer-code modes whose attend path dequantizes
    /// ([`CostTracker::dequant_rows`] accounting).  F16 is a precision
    /// change, not a quantization — its reads don't count as dequants.
    #[inline]
    pub fn is_quantized(&self) -> bool {
        matches!(self.dtype, KvDtype::Int8 | KvDtype::Int4)
    }

    /// The vector level this cache's kernels dispatch through.
    #[inline]
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// Override the kernel dispatch level.  Benches (simd-vs-scalar
    /// tables) and the equivalence property suites only — the engine
    /// always runs what [`crate::simd::detect`] stamped at construction.
    pub fn set_simd_level(&mut self, level: SimdLevel) {
        self.simd = level;
    }

    /// First position of the f32 staging tail (compressed modes):
    /// positions at or beyond this sit in the not-yet-converted partial
    /// tile.
    #[inline]
    fn staged_from(&self) -> usize {
        (self.len / self.page_size) * self.page_size
    }

    /// Base offset of `(head, completed tile)`'s rows in the f16 planes
    /// (F16 mode is never tiered, so the layout is always flat).
    #[inline]
    fn h_base(&self, h: usize, tile: usize) -> usize {
        (h * self.cap + tile * self.page_size) * self.d
    }

    /// Base offset of `(head, completed tile)`'s packed int4 rows (two
    /// codes per byte; Int4 mode is never tiered).
    #[inline]
    fn p4_base(&self, h: usize, tile: usize) -> usize {
        (h * self.cap + tile * self.page_size) * self.d / 2
    }

    /// Base offset of `(head, completed tile)`'s int8 rows in `kq`/`vq`.
    /// Flat mode: the contiguous `[n_kv, cap, d]` layout.  Tiered mode:
    /// the tile's hot arena slot — asserting residency, because reading
    /// a demoted tile's codes would be silent corruption (the ensure /
    /// tick-boundary promotion paths uphold this invariant).
    #[inline]
    fn q_base(&self, h: usize, tile: usize) -> usize {
        match &self.tier {
            None => (h * self.cap + tile * self.page_size) * self.d,
            Some(t) => {
                let slot = t.slot_of[tile];
                assert!(
                    slot != NO_SLOT,
                    "quantized read of non-hot tile {tile} (layer {})",
                    t.layer
                );
                (slot as usize * self.n_kv + h) * self.page_size * self.d
            }
        }
    }

    /// Number of completed (quantized) tiles.
    #[inline]
    fn completed_tiles(&self) -> usize {
        self.len / self.page_size
    }

    /// Whether this cache runs the hot/warm/cold tier machinery.
    #[inline]
    pub fn is_tiered(&self) -> bool {
        self.tier.is_some()
    }

    /// Tiles currently resident in the hot arena (tiered mode; 0 flat).
    pub fn hot_tiles(&self) -> usize {
        self.tier.as_ref().map_or(0, |t| t.hot_count)
    }

    /// Residency tier of a completed tile — `None` for flat caches, the
    /// staging tail, or out-of-range tiles.  Diagnostics/tests.
    pub fn tile_tier(&self, tile: usize) -> Option<TileTier> {
        let t = self.tier.as_ref()?;
        if tile >= self.completed_tiles() {
            return None;
        }
        if t.slot_of.get(tile).copied().unwrap_or(NO_SLOT) != NO_SLOT {
            Some(TileTier::Hot)
        } else if t.warm.get(tile).is_some_and(|w| w.is_some()) {
            Some(TileTier::Warm)
        } else {
            Some(TileTier::Cold)
        }
    }

    /// Drain this cache's promotion/demotion counters.
    pub fn take_tier_stats(&mut self) -> TierStats {
        self.tier.as_mut().map(|t| std::mem::take(&mut t.stats)).unwrap_or_default()
    }

    /// Register a freshly completed tile in the hot arena: claim a slot
    /// (possibly demoting the LRU tile), stamp the current spill owner,
    /// and mark it most-recently used.
    fn tier_complete_tile(&mut self, tile: usize) {
        let slot = self.tier_alloc_slot();
        let Some(t) = self.tier.as_mut() else {
            return;
        };
        t.grow_to(tile);
        t.tile_owner[tile] = t.self_owner;
        t.slot_of[tile] = slot;
        t.tile_of[slot as usize] = tile as u32;
        t.hot_count += 1;
        t.touch(tile);
    }

    /// Grab a free hot-arena slot: reuse a freed one, demote the LRU
    /// tile when at budget, or grow the arena (demand promotions may
    /// overshoot the budget; planned maintenance trims back).
    fn tier_alloc_slot(&mut self) -> u32 {
        if let Some(s) = self.tier.as_mut().and_then(|t| t.free_slots.pop()) {
            return s;
        }
        let at_budget =
            self.tier.as_ref().is_some_and(|t| t.hot_count >= t.cfg.hot_tile_budget);
        if at_budget && self.tier_demote_lru() {
            if let Some(s) = self.tier.as_mut().and_then(|t| t.free_slots.pop()) {
                return s;
            }
        }
        // grow the arena by one slot
        let slot_elems = self.n_kv * self.page_size * self.d;
        let slot = (self.kq.len() / slot_elems.max(1)) as u32;
        self.kq.resize(self.kq.len() + slot_elems, 0);
        self.vq.resize(self.vq.len() + slot_elems, 0);
        if let Some(t) = self.tier.as_mut() {
            t.tile_of.push(NO_SLOT);
        }
        slot
    }

    /// Demote the least-recently-needed hot tile, skipping tiles needed
    /// in the current ensure epoch.  False when nothing is demotable.
    fn tier_demote_lru(&mut self) -> bool {
        let Some(t) = self.tier.as_mut() else {
            return false;
        };
        let mut protected: Vec<Reverse<(u64, u32)>> = Vec::new();
        let victim = loop {
            let Some(Reverse((s, tile))) = t.lru.pop() else {
                break None;
            };
            let ti = tile as usize;
            // stale entry (restamped, demoted, or truncated away)?
            if ti >= t.slot_of.len() || t.slot_of[ti] == NO_SLOT || t.stamp[ti] != s {
                continue;
            }
            if t.visited[ti] == t.epoch && t.epoch != 0 {
                protected.push(Reverse((s, tile)));
                continue;
            }
            break Some(ti);
        };
        for p in protected {
            t.lru.push(p);
        }
        match victim {
            Some(tile) => self.tier_demote_tile(tile),
            None => false,
        }
    }

    /// Demote one hot tile: spill its exact int8 payload (write-once),
    /// build the warm int4 shadow, free the slot.  False if not hot.
    fn tier_demote_tile(&mut self, tile: usize) -> bool {
        let ps = self.page_size;
        let d = self.d;
        let n_kv = self.n_kv;
        let td = ps * d;
        let nt = self.cap.div_ceil(ps);
        let Some(t) = self.tier.as_mut() else {
            return false;
        };
        let slot = match t.slot_of.get(tile) {
            Some(&s) if s != NO_SLOT => s as usize,
            _ => return false,
        };
        let key = TileKey { owner: t.tile_owner[tile], layer: t.layer, tile: tile as u32 };
        {
            let mut store = match t.store.lock() {
                Ok(g) => g,
                // poisoned store mutex: a worker already panicked
                // mid-spill; state is lost
                Err(_) => panic!("tile store mutex poisoned during demotion"),
            };
            if !store.contains(key) {
                t.payload.clear();
                t.payload.reserve(2 * n_kv * td);
                for h in 0..n_kv {
                    let base = (slot * n_kv + h) * td;
                    t.payload.extend(self.kq[base..base + td].iter().map(|&c| c as u8));
                }
                for h in 0..n_kv {
                    let base = (slot * n_kv + h) * td;
                    t.payload.extend(self.vq[base..base + td].iter().map(|&c| c as u8));
                }
                if let Err(e) = store.put(key, &t.payload) {
                    // spill-write failure is
                    // unrecoverable mid-append: the tile's bytes would be
                    // lost on slot reuse.  The error is typed
                    // (TileStoreError) and exercised at the store layer.
                    panic!("KV tile spill failed for {key}: {e}");
                }
            }
        }
        // warm shadow: int4 codes of the (dequantized) hot payload
        let mut wt = WarmTile {
            k4: vec![0u8; n_kv * td / 2],
            v4: vec![0u8; n_kv * td / 2],
            affine: vec![0.0f32; n_kv * 4],
        };
        if t.scratch.len() < td {
            t.scratch.resize(td, 0.0);
        }
        for h in 0..n_kv {
            let base = (slot * n_kv + h) * td;
            let si = h * nt + tile;
            dequantize_q8(
                &self.kq[base..base + td],
                self.kscale[si],
                self.kzero[si],
                &mut t.scratch[..td],
            );
            let (ks4, kz4) = quantize_q4(&t.scratch[..td], &mut wt.k4[h * td / 2..(h + 1) * td / 2]);
            dequantize_q8(
                &self.vq[base..base + td],
                self.vscale[si],
                self.vzero[si],
                &mut t.scratch[..td],
            );
            let (vs4, vz4) = quantize_q4(&t.scratch[..td], &mut wt.v4[h * td / 2..(h + 1) * td / 2]);
            wt.affine[h * 4] = ks4;
            wt.affine[h * 4 + 1] = kz4;
            wt.affine[h * 4 + 2] = vs4;
            wt.affine[h * 4 + 3] = vz4;
        }
        if t.warm[tile].replace(Box::new(wt)).is_none() {
            t.warm_count += 1;
        }
        t.warm_order.push_back(tile as u32);
        // age warm shadows beyond the warm budget down to cold
        while t.warm_count > t.cfg.warm_tile_budget {
            let Some(old) = t.warm_order.pop_front() else {
                break;
            };
            let oi = old as usize;
            // skip entries that re-promoted or re-demoted since queuing
            if oi < t.warm.len()
                && t.slot_of[oi] == NO_SLOT
                && oi != tile
                && t.warm[oi].take().is_some()
            {
                t.warm_count -= 1;
            }
        }
        t.slot_of[tile] = NO_SLOT;
        t.tile_of[slot] = NO_SLOT;
        t.free_slots.push(slot as u32);
        t.hot_count -= 1;
        t.stats.tiles_demoted += 1;
        true
    }

    /// Promote a demoted tile back into the hot arena from its spill
    /// record — byte-exact by the write-once store contract.  No-op for
    /// hot tiles.
    fn tier_promote_tile(&mut self, tile: usize) -> Result<(), TileStoreError> {
        let (already, in_range) = match self.tier.as_ref() {
            None => return Ok(()),
            Some(t) => (
                t.slot_of.get(tile).copied().unwrap_or(NO_SLOT) != NO_SLOT,
                tile < t.slot_of.len(),
            ),
        };
        if already {
            return Ok(());
        }
        if !in_range || tile >= self.completed_tiles() {
            return Err(TileStoreError::Corrupt(format!(
                "promotion of unknown tile {tile} (completed {})",
                self.completed_tiles()
            )));
        }
        let slot = self.tier_alloc_slot() as usize;
        let n_kv = self.n_kv;
        let td = self.page_size * self.d;
        let Some(t) = self.tier.as_mut() else {
            return Ok(());
        };
        let key = TileKey { owner: t.tile_owner[tile], layer: t.layer, tile: tile as u32 };
        {
            let mut store = t
                .store
                .lock()
                .map_err(|_| TileStoreError::Corrupt("tile store mutex poisoned".into()))?;
            store.get(key, &mut t.payload)?;
        }
        let expect = 2 * n_kv * td;
        if t.payload.len() != expect {
            return Err(TileStoreError::Corrupt(format!(
                "payload for {key} is {} bytes, expected {expect}",
                t.payload.len()
            )));
        }
        for h in 0..n_kv {
            let dst = (slot * n_kv + h) * td;
            let src = h * td;
            for i in 0..td {
                self.kq[dst + i] = t.payload[src + i] as i8;
            }
            let src = (n_kv + h) * td;
            for i in 0..td {
                self.vq[dst + i] = t.payload[src + i] as i8;
            }
        }
        t.slot_of[tile] = slot as u32;
        t.tile_of[slot] = tile as u32;
        t.hot_count += 1;
        t.stats.tiles_promoted += 1;
        if t.warm[tile].take().is_some() {
            t.warm_count -= 1;
        }
        t.touch(tile);
        Ok(())
    }

    /// Promote every completed tile the selection touches (demand path,
    /// run in the policy phase before the attention kernels read).
    /// Counts a prefetch hit per already-hot needed tile and a miss per
    /// demand promotion; needed tiles are protected from same-call
    /// demotion via the visit epoch.
    pub fn ensure_hot_for(&mut self, sel: &IndexSet) -> Result<(), TileStoreError> {
        if self.tier.is_none() {
            return Ok(());
        }
        let ps = self.page_size;
        let completed = self.completed_tiles();
        if let Some(t) = self.tier.as_mut() {
            t.epoch += 1;
        }
        for h in 0..sel.n_heads() {
            for &p in sel.head(h) {
                let tile = p as usize / ps;
                if tile >= completed {
                    continue; // staging tail — always resident
                }
                self.tier_need_tile(tile)?;
            }
        }
        Ok(())
    }

    /// Promote every completed tile (dense fallback on a tiered layer).
    pub fn ensure_all_hot(&mut self) -> Result<(), TileStoreError> {
        if self.tier.is_none() {
            return Ok(());
        }
        let completed = self.completed_tiles();
        if let Some(t) = self.tier.as_mut() {
            t.epoch += 1;
        }
        for tile in 0..completed {
            self.tier_need_tile(tile)?;
        }
        Ok(())
    }

    /// Mark `tile` needed in the current epoch: hit-count or promote.
    fn tier_need_tile(&mut self, tile: usize) -> Result<(), TileStoreError> {
        let Some(t) = self.tier.as_mut() else {
            return Ok(());
        };
        if t.visited.get(tile).copied() == Some(t.epoch) {
            return Ok(());
        }
        t.grow_to(tile);
        t.visited[tile] = t.epoch;
        if t.slot_of[tile] != NO_SLOT {
            t.stats.prefetch_hits += 1;
            t.touch(tile);
            Ok(())
        } else {
            t.stats.prefetch_misses += 1;
            self.tier_promote_tile(tile)
        }
    }

    /// Apply a tick-boundary tile plan: demote first (freeing slots),
    /// then stage the hinted promotions.  Planned promotions are the
    /// prefetch — they count in `tiles_promoted` but not as misses.
    pub fn apply_tile_plan(
        &mut self,
        promote: &[u32],
        demote: &[u32],
    ) -> Result<(), TileStoreError> {
        if self.tier.is_none() {
            return Ok(());
        }
        let completed = self.completed_tiles();
        for &tile in demote {
            if (tile as usize) < completed {
                self.tier_demote_tile(tile as usize);
            }
        }
        for &tile in promote {
            if (tile as usize) < completed {
                self.tier_promote_tile(tile as usize)?;
            }
        }
        Ok(())
    }

    /// Dequantize the warm int4 shadow of `pos`'s key row into
    /// `out[..d]`; false when the tile holds no warm shadow.
    /// Diagnostics/tests only — compute kernels never read the warm
    /// tier (it is tolerance-gated, not exact).
    pub fn warm_key_row(&self, h: usize, pos: usize, out: &mut [f32]) -> bool {
        let Some(t) = self.tier.as_ref() else {
            return false;
        };
        let ps = self.page_size;
        let tile = pos / ps;
        let Some(Some(w)) = t.warm.get(tile) else {
            return false;
        };
        let td = ps * self.d;
        let half = self.d / 2;
        let row = h * td / 2 + (pos % ps) * half;
        dequantize_q4(&w.k4[row..row + half], w.affine[h * 4], w.affine[h * 4 + 1], &mut out[..self.d]);
        true
    }

    /// Reset tier bookkeeping for tiles at or beyond completed-tile
    /// index `keep`, refreshing the spill owner so re-completed tiles
    /// get fresh keys (their content diverges from the old records).
    fn tier_truncate(&mut self, keep: usize) {
        let Some(t) = self.tier.as_mut() else {
            return;
        };
        for tile in keep..t.slot_of.len() {
            let slot = t.slot_of[tile];
            if slot != NO_SLOT {
                t.tile_of[slot as usize] = NO_SLOT;
                t.free_slots.push(slot);
                t.hot_count -= 1;
            }
            if t.warm[tile].take().is_some() {
                t.warm_count -= 1;
            }
        }
        t.slot_of.truncate(keep);
        t.tile_owner.truncate(keep);
        t.stamp.truncate(keep);
        t.visited.truncate(keep);
        t.warm.truncate(keep);
        t.warm_order.retain(|&x| (x as usize) < keep);
        t.self_owner = match t.store.lock() {
            Ok(mut s) => s.alloc_owner(),
            // poisoned store mutex: a worker already panicked mid-spill;
            // state is lost
            Err(_) => panic!("tile store mutex poisoned during truncate"),
        };
    }

    /// KV bytes resident for the `len` stored positions (storage the
    /// tokens actually occupy; excludes unused capacity).  Compressed
    /// modes count the completed tiles at their stored width, the
    /// per-tile scale/zero params (int8/int4), and the f32 staging tail.
    pub fn kv_bytes(&self) -> usize {
        let rows = self.n_kv * self.d * 2; // K + V elements per position
        match self.dtype {
            KvDtype::F32 => self.len * rows * 4,
            KvDtype::F16 => {
                let full = self.staged_from();
                let staged = self.len - full;
                full * rows * 2 + staged * rows * 4
            }
            KvDtype::Int4 => {
                let full = self.staged_from();
                let staged = self.len - full;
                let tiles = full / self.page_size;
                let params = tiles * self.n_kv * 4 * 4;
                full * rows / 2 + staged * rows * 4 + params
            }
            KvDtype::Int8 => {
                let full = self.staged_from();
                let staged = self.len - full;
                let tiles = full / self.page_size;
                let params = tiles * self.n_kv * 4 * 4;
                match &self.tier {
                    None => full * rows + staged * rows * 4 + params,
                    // tiered: the allocated hot arena (however many slots
                    // exist), plus the warm int4 shadows + their affine
                    // params — cold tiles cost nothing resident
                    Some(t) => {
                        let td = self.page_size * self.d;
                        let warm = t.warm_count * (self.n_kv * td + self.n_kv * 16);
                        self.kq.len() + self.vq.len() + staged * rows * 4 + params + warm
                    }
                }
            }
        }
    }

    /// Append one position: `k_new`/`v_new` are `[n_kv * d]` (head-major).
    pub fn push(&mut self, k_new: &[f32], v_new: &[f32]) {
        assert!(self.len < self.cap, "KV cache overflow (cap {})", self.cap);
        debug_assert_eq!(k_new.len(), self.n_kv * self.d);
        let pos = self.len;
        let page = pos / self.page_size;
        let r = pos % self.page_size;
        let fresh_page = r == 0;
        for h in 0..self.n_kv {
            let dst = match self.dtype {
                KvDtype::F32 => (h * self.cap + pos) * self.d,
                // compressed modes share the f32 staging-tile layout
                _ => (h * self.page_size + r) * self.d,
            };
            self.k[dst..dst + self.d].copy_from_slice(&k_new[h * self.d..(h + 1) * self.d]);
            self.v[dst..dst + self.d].copy_from_slice(&v_new[h * self.d..(h + 1) * self.d]);
            // update page min/max
            let pb = ((h * self.cap.div_ceil(self.page_size)) + page) * 2 * self.d;
            let (mins, rest) = self.pages[pb..pb + 2 * self.d].split_at_mut(self.d);
            let maxs = rest;
            let krow = &k_new[h * self.d..(h + 1) * self.d];
            if fresh_page {
                mins.copy_from_slice(krow);
                maxs.copy_from_slice(krow);
            } else {
                for i in 0..self.d {
                    mins[i] = mins[i].min(krow[i]);
                    maxs[i] = maxs[i].max(krow[i]);
                }
            }
        }
        self.len += 1;
        if self.dtype.is_compressed() && r == self.page_size - 1 {
            self.complete_tile(page);
        }
    }

    /// Convert the (full) staging tile into this mode's completed-tile
    /// store.  Once converted, the tile's bytes never change — the
    /// byte-stable boundary CoW forks share across all compressed modes.
    fn complete_tile(&mut self, tile: usize) {
        match self.dtype {
            KvDtype::F32 => unreachable!("F32 caches have no staging tiles"),
            KvDtype::F16 => self.halve_tile(tile),
            KvDtype::Int8 => self.quantize_tile(tile),
            KvDtype::Int4 => self.quantize_tile_q4(tile),
        }
    }

    /// Convert the (full) staging tile to binary16 planes (F16 mode).
    fn halve_tile(&mut self, tile: usize) {
        let td = self.page_size * self.d;
        for h in 0..self.n_kv {
            let src = h * td;
            let dst = self.h_base(h, tile);
            for i in 0..td {
                self.kh[dst + i] = f32_to_f16(self.k[src + i]);
                self.vh[dst + i] = f32_to_f16(self.v[src + i]);
            }
        }
    }

    /// Quantize the (full) staging tile into the packed int4 store
    /// (Int4 mode; never tiered, so the planes are always flat).
    fn quantize_tile_q4(&mut self, tile: usize) {
        let td = self.page_size * self.d;
        let half = td / 2;
        let nt = self.cap.div_ceil(self.page_size);
        for h in 0..self.n_kv {
            let src = h * td;
            let dst = self.p4_base(h, tile);
            let (ks, kz) = quantize_q4(&self.k[src..src + td], &mut self.k4[dst..dst + half]);
            let (vs, vz) = quantize_q4(&self.v[src..src + td], &mut self.v4[dst..dst + half]);
            self.kscale[h * nt + tile] = ks;
            self.kzero[h * nt + tile] = kz;
            self.vscale[h * nt + tile] = vs;
            self.vzero[h * nt + tile] = vz;
        }
    }

    /// Quantize the (full) staging tile into the int8 store (Int8 mode).
    /// Tiered caches first claim a hot-arena slot for the new tile (which
    /// may demote the LRU tile at budget) and stamp it with the current
    /// spill owner — the freshly quantized bytes are the canonical
    /// payload this tile spills and promotes forever after.
    fn quantize_tile(&mut self, tile: usize) {
        if self.tier.is_some() {
            self.tier_complete_tile(tile);
        }
        let td = self.page_size * self.d;
        let nt = self.cap.div_ceil(self.page_size);
        for h in 0..self.n_kv {
            let src = h * td;
            let dst = self.q_base(h, tile);
            let (ks, kz) = quantize_q8(&self.k[src..src + td], &mut self.kq[dst..dst + td]);
            let (vs, vz) = quantize_q8(&self.v[src..src + td], &mut self.vq[dst..dst + td]);
            self.kscale[h * nt + tile] = ks;
            self.kzero[h * nt + tile] = kz;
            self.vscale[h * nt + tile] = vs;
            self.vzero[h * nt + tile] = vz;
        }
    }

    /// Raw f32 key row.  Compressed modes: only valid for staged (tail)
    /// positions — completed tiles have no f32 representation.
    #[inline]
    pub fn key(&self, h: usize, pos: usize) -> &[f32] {
        let o = match self.dtype {
            KvDtype::F32 => (h * self.cap + pos) * self.d,
            _ => {
                assert!(pos >= self.staged_from(), "f32 key read of compressed position {pos}");
                (h * self.page_size + pos % self.page_size) * self.d
            }
        };
        &self.k[o..o + self.d]
    }

    /// Raw f32 value row (same staging restriction as [`KvCache::key`]).
    #[inline]
    pub fn val(&self, h: usize, pos: usize) -> &[f32] {
        let o = match self.dtype {
            KvDtype::F32 => (h * self.cap + pos) * self.d,
            _ => {
                assert!(pos >= self.staged_from(), "f32 val read of compressed position {pos}");
                (h * self.page_size + pos % self.page_size) * self.d
            }
        };
        &self.v[o..o + self.d]
    }

    /// `dot(q, key(h, pos))` in whatever precision the row is stored:
    /// f32/staged rows use the exact [`simd::dot`], f16 rows the
    /// convert-on-read [`simd::dot_f16`], int8/int4 rows the fused
    /// [`simd::qk_dot_q8`] / [`simd::qk_dot_q4`] (no dequantized
    /// materialization).
    #[inline]
    pub fn dot_key(&self, h: usize, pos: usize, q: &[f32]) -> f32 {
        let lv = self.simd;
        if self.dtype == KvDtype::F32 || pos >= self.staged_from() {
            return simd::dot(lv, q, self.key(h, pos));
        }
        let tile = pos / self.page_size;
        let nt = self.cap.div_ceil(self.page_size);
        match self.dtype {
            KvDtype::F16 => {
                let o = self.h_base(h, tile) + (pos % self.page_size) * self.d;
                simd::dot_f16(lv, q, &self.kh[o..o + self.d])
            }
            KvDtype::Int8 => {
                let o = self.q_base(h, tile) + (pos % self.page_size) * self.d;
                simd::qk_dot_q8(
                    lv,
                    q,
                    &self.kq[o..o + self.d],
                    self.kscale[h * nt + tile],
                    self.kzero[h * nt + tile],
                )
            }
            KvDtype::Int4 => {
                let half = self.d / 2;
                let o = self.p4_base(h, tile) + (pos % self.page_size) * half;
                simd::qk_dot_q4(
                    lv,
                    q,
                    &self.k4[o..o + half],
                    self.kscale[h * nt + tile],
                    self.kzero[h * nt + tile],
                )
            }
            KvDtype::F32 => unreachable!(),
        }
    }

    /// `out += w * val(h, pos)` — f32/staged rows via [`simd::axpy`],
    /// f16 rows via [`simd::axpy_f16`], int8/int4 rows via the fused
    /// dequantize-on-attend [`simd::axpy_q8`] / [`simd::axpy_q4`].
    #[inline]
    pub fn add_val(&self, h: usize, pos: usize, w: f32, out: &mut [f32]) {
        let lv = self.simd;
        if self.dtype == KvDtype::F32 || pos >= self.staged_from() {
            return simd::axpy(lv, out, w, self.val(h, pos));
        }
        let tile = pos / self.page_size;
        let nt = self.cap.div_ceil(self.page_size);
        match self.dtype {
            KvDtype::F16 => {
                let o = self.h_base(h, tile) + (pos % self.page_size) * self.d;
                simd::axpy_f16(lv, out, w, &self.vh[o..o + self.d]);
            }
            KvDtype::Int8 => {
                let o = self.q_base(h, tile) + (pos % self.page_size) * self.d;
                simd::axpy_q8(
                    lv,
                    out,
                    w,
                    &self.vq[o..o + self.d],
                    self.vscale[h * nt + tile],
                    self.vzero[h * nt + tile],
                );
            }
            KvDtype::Int4 => {
                let half = self.d / 2;
                let o = self.p4_base(h, tile) + (pos % self.page_size) * half;
                simd::axpy_q4(
                    lv,
                    out,
                    w,
                    &self.v4[o..o + half],
                    self.vscale[h * nt + tile],
                    self.vzero[h * nt + tile],
                );
            }
            KvDtype::F32 => unreachable!(),
        }
    }

    /// The stored int8 key row and its tile `(scale, zero)` — `None` for
    /// f32 caches and staged positions.  Diagnostics/tests only (e.g.
    /// asserting CoW forks share quantized tiles byte-for-byte).
    pub fn quantized_key_row(&self, h: usize, pos: usize) -> Option<(&[i8], f32, f32)> {
        if self.dtype != KvDtype::Int8 || pos >= self.staged_from() {
            return None;
        }
        let tile = pos / self.page_size;
        if self.tier.is_some() && self.tile_tier(tile) != Some(TileTier::Hot) {
            return None; // demoted tiles have no addressable int8 rows
        }
        let nt = self.cap.div_ceil(self.page_size);
        let o = self.q_base(h, tile) + (pos % self.page_size) * self.d;
        Some((&self.kq[o..o + self.d], self.kscale[h * nt + tile], self.kzero[h * nt + tile]))
    }

    /// The stored binary16 key row — `None` for non-F16 caches and
    /// staged positions.  Diagnostics/tests only (CoW byte-sharing
    /// assertions, mirroring [`KvCache::quantized_key_row`]).
    pub fn f16_key_row(&self, h: usize, pos: usize) -> Option<&[u16]> {
        if self.dtype != KvDtype::F16 || pos >= self.staged_from() {
            return None;
        }
        let o = self.h_base(h, pos / self.page_size) + (pos % self.page_size) * self.d;
        Some(&self.kh[o..o + self.d])
    }

    /// The stored packed int4 key row (`d/2` bytes) and its tile
    /// `(scale, zero)` — `None` for non-Int4 caches and staged
    /// positions.  Diagnostics/tests only.
    pub fn packed_key_row(&self, h: usize, pos: usize) -> Option<(&[u8], f32, f32)> {
        if self.dtype != KvDtype::Int4 || pos >= self.staged_from() {
            return None;
        }
        let tile = pos / self.page_size;
        let nt = self.cap.div_ceil(self.page_size);
        let half = self.d / 2;
        let o = self.p4_base(h, tile) + (pos % self.page_size) * half;
        Some((&self.k4[o..o + half], self.kscale[h * nt + tile], self.kzero[h * nt + tile]))
    }

    /// Score one KV tile for head `h`: writes `dot(q, key(h, p)) * scale`
    /// for every position `p` of the tile below `upto` into `out[..n]`,
    /// returning `n` (0 when the tile is empty under the clamp).
    ///
    /// This is the tile-major scoring primitive: the dtype dispatch, the
    /// tile's quantization `(scale, zero)` pair, the base offset, and the
    /// query's element sum (the int8 zero-point term) are all resolved
    /// ONCE per call, and the inner loop runs over contiguous rows.
    /// Results are bitwise-identical to calling [`KvCache::dot_key`] per
    /// position and scaling (see `attention::reference`).
    pub fn score_tile(
        &self,
        h: usize,
        tile: usize,
        upto: usize,
        q: &[f32],
        scale: f32,
        out: &mut [f32],
    ) -> usize {
        let ps = self.page_size;
        let d = self.d;
        let t0 = tile * ps;
        let hi = upto.min(self.len);
        if t0 >= hi {
            return 0;
        }
        let n = (hi - t0).min(ps);
        let lv = self.simd;
        match self.dtype {
            KvDtype::F32 => {
                let base = (h * self.cap + t0) * d;
                let rows = &self.k[base..base + n * d];
                for (j, o) in out[..n].iter_mut().enumerate() {
                    *o = simd::dot(lv, q, &rows[j * d..(j + 1) * d]) * scale;
                }
            }
            _ if t0 >= self.staged_from() => {
                // the (single) f32 staging tail tile, shared by every
                // compressed mode
                let base = h * ps * d;
                let rows = &self.k[base..base + n * d];
                for (j, o) in out[..n].iter_mut().enumerate() {
                    *o = simd::dot(lv, q, &rows[j * d..(j + 1) * d]) * scale;
                }
            }
            KvDtype::F16 => {
                let base = self.h_base(h, tile);
                let rows = &self.kh[base..base + n * d];
                for (j, o) in out[..n].iter_mut().enumerate() {
                    *o = simd::dot_f16(lv, q, &rows[j * d..(j + 1) * d]) * scale;
                }
            }
            KvDtype::Int8 => {
                let nt = self.cap.div_ceil(ps);
                let ks = self.kscale[h * nt + tile];
                let kz = self.kzero[h * nt + tile];
                let q_sum = simd::sum4(lv, q);
                let base = self.q_base(h, tile);
                let rows = &self.kq[base..base + n * d];
                for (j, o) in out[..n].iter_mut().enumerate() {
                    *o = (ks * simd::dot_i8(lv, q, &rows[j * d..(j + 1) * d]) + kz * q_sum)
                        * scale;
                }
            }
            KvDtype::Int4 => {
                let nt = self.cap.div_ceil(ps);
                let ks = self.kscale[h * nt + tile];
                let kz = self.kzero[h * nt + tile];
                let q_sum = simd::sum4(lv, q);
                let half = d / 2;
                let base = self.p4_base(h, tile);
                let rows = &self.k4[base..base + n * half];
                for (j, o) in out[..n].iter_mut().enumerate() {
                    *o = (ks * simd::dot_i4(lv, q, &rows[j * half..(j + 1) * half])
                        + kz * q_sum)
                        * scale;
                }
            }
        }
        n
    }

    /// Weighted-value accumulation over one KV tile for head `h`:
    /// `out += w[j] * val(h, t0 + j)` for every tile position below
    /// `upto` whose weight exceeds the shared `1e-9` skip threshold.
    /// Returns the tile's position count `n` (reads `w[..n]`).  Per-tile
    /// dequantization params resolved once; row accumulation matches
    /// [`KvCache::add_val`] bitwise.
    pub fn attend_tile(
        &self,
        h: usize,
        tile: usize,
        upto: usize,
        w: &[f32],
        out: &mut [f32],
    ) -> usize {
        let ps = self.page_size;
        let d = self.d;
        let t0 = tile * ps;
        let hi = upto.min(self.len);
        if t0 >= hi {
            return 0;
        }
        let n = (hi - t0).min(ps);
        let lv = self.simd;
        match self.dtype {
            KvDtype::F32 => {
                let base = (h * self.cap + t0) * d;
                let rows = &self.v[base..base + n * d];
                for (j, &wj) in w[..n].iter().enumerate() {
                    if wj > 1e-9 {
                        simd::axpy(lv, out, wj, &rows[j * d..(j + 1) * d]);
                    }
                }
            }
            _ if t0 >= self.staged_from() => {
                let base = h * ps * d;
                let rows = &self.v[base..base + n * d];
                for (j, &wj) in w[..n].iter().enumerate() {
                    if wj > 1e-9 {
                        simd::axpy(lv, out, wj, &rows[j * d..(j + 1) * d]);
                    }
                }
            }
            KvDtype::F16 => {
                let base = self.h_base(h, tile);
                let rows = &self.vh[base..base + n * d];
                for (j, &wj) in w[..n].iter().enumerate() {
                    if wj > 1e-9 {
                        simd::axpy_f16(lv, out, wj, &rows[j * d..(j + 1) * d]);
                    }
                }
            }
            KvDtype::Int8 => {
                let nt = self.cap.div_ceil(ps);
                let vs = self.vscale[h * nt + tile];
                let vz = self.vzero[h * nt + tile];
                let base = self.q_base(h, tile);
                let rows = &self.vq[base..base + n * d];
                for (j, &wj) in w[..n].iter().enumerate() {
                    if wj > 1e-9 {
                        simd::axpy_q8(lv, out, wj, &rows[j * d..(j + 1) * d], vs, vz);
                    }
                }
            }
            KvDtype::Int4 => {
                let nt = self.cap.div_ceil(ps);
                let vs = self.vscale[h * nt + tile];
                let vz = self.vzero[h * nt + tile];
                let half = d / 2;
                let base = self.p4_base(h, tile);
                let rows = &self.v4[base..base + n * half];
                for (j, &wj) in w[..n].iter().enumerate() {
                    if wj > 1e-9 {
                        simd::axpy_q4(lv, out, wj, &rows[j * half..(j + 1) * half], vs, vz);
                    }
                }
            }
        }
        n
    }

    /// [`KvCache::dot_key`] with the query's element sum precomputed (the
    /// int8/int4 zero-point term, hoistable per query row).  Bitwise-equal
    /// to `dot_key` when `q_sum == simd::sum4(lv, q)` — the sparse kernels
    /// use this to amortize the sum over arbitrary (non-tile-run) index
    /// sets.  F32/F16 rows ignore `q_sum` (no zero-point term).
    #[inline]
    pub fn dot_key_with_sum(&self, h: usize, pos: usize, q: &[f32], q_sum: f32) -> f32 {
        let lv = self.simd;
        if self.dtype == KvDtype::F32 || pos >= self.staged_from() {
            return simd::dot(lv, q, self.key(h, pos));
        }
        let tile = pos / self.page_size;
        let nt = self.cap.div_ceil(self.page_size);
        match self.dtype {
            KvDtype::F16 => {
                let o = self.h_base(h, tile) + (pos % self.page_size) * self.d;
                simd::dot_f16(lv, q, &self.kh[o..o + self.d])
            }
            KvDtype::Int8 => {
                let o = self.q_base(h, tile) + (pos % self.page_size) * self.d;
                self.kscale[h * nt + tile] * simd::dot_i8(lv, q, &self.kq[o..o + self.d])
                    + self.kzero[h * nt + tile] * q_sum
            }
            KvDtype::Int4 => {
                let half = self.d / 2;
                let o = self.p4_base(h, tile) + (pos % self.page_size) * half;
                self.kscale[h * nt + tile] * simd::dot_i4(lv, q, &self.k4[o..o + half])
                    + self.kzero[h * nt + tile] * q_sum
            }
            KvDtype::F32 => unreachable!(),
        }
    }

    /// (min, max) key summary of `page` for head `h`.
    pub fn page_summary(&self, h: usize, page: usize) -> (&[f32], &[f32]) {
        let pb = ((h * self.cap.div_ceil(self.page_size)) + page) * 2 * self.d;
        (&self.pages[pb..pb + self.d], &self.pages[pb + self.d..pb + 2 * self.d])
    }

    pub fn clear(&mut self) {
        self.len = 0;
        if self.tier.is_some() {
            self.tier_truncate(0);
        }
    }

    /// Truncate to the first `n` positions (prefix-cache snapshot forks).
    /// The (now partial) last page's min/max summary is rebuilt from the
    /// stored keys so Quest-style page bounds stay exact after
    /// truncation.  Int8 mode: a boundary inside a completed tile
    /// dequantizes that tile's surviving rows back into the staging tail
    /// (they re-quantize when the tile refills); tile-aligned boundaries
    /// — the common case, since prefix-cache snapshots are block-aligned
    /// and blocks equal tiles — keep every quantized tile byte-for-byte.
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.len, "truncate {n} beyond len {}", self.len);
        let old_len = self.len;
        self.len = n;
        if self.tier.is_some() {
            // engine truncation points are block-aligned and blocks are a
            // multiple of the tile size, so a mid-tile boundary here is a
            // caller bug — and honoring it would require reading possibly
            // non-hot codes back into staging
            assert!(
                n % self.page_size == 0,
                "tiered KV truncate must be tile-aligned (n={n}, tile={})",
                self.page_size
            );
            self.tier_truncate(n / self.page_size);
        }
        if n == 0 {
            return;
        }
        let ps = self.page_size;
        let d = self.d;
        let tail = n % ps;
        if self.dtype.is_compressed() && tail != 0 {
            let tile = n / ps;
            if old_len / ps > tile {
                // the tail tile had completed: restore its surviving rows
                // into staging from the compressed store (one
                // convert/dequant round-trip, deterministic per mode)
                let nt = self.cap.div_ceil(ps);
                for h in 0..self.n_kv {
                    for r in 0..tail {
                        let dst = (h * ps + r) * d;
                        match self.dtype {
                            KvDtype::F16 => {
                                let src = (h * self.cap + tile * ps + r) * d;
                                for i in 0..d {
                                    self.k[dst + i] = f16_to_f32(self.kh[src + i]);
                                    self.v[dst + i] = f16_to_f32(self.vh[src + i]);
                                }
                            }
                            KvDtype::Int8 => {
                                let (ks, kz) =
                                    (self.kscale[h * nt + tile], self.kzero[h * nt + tile]);
                                let (vs, vz) =
                                    (self.vscale[h * nt + tile], self.vzero[h * nt + tile]);
                                let src = (h * self.cap + tile * ps + r) * d;
                                dequantize_q8(
                                    &self.kq[src..src + d],
                                    ks,
                                    kz,
                                    &mut self.k[dst..dst + d],
                                );
                                dequantize_q8(
                                    &self.vq[src..src + d],
                                    vs,
                                    vz,
                                    &mut self.v[dst..dst + d],
                                );
                            }
                            KvDtype::Int4 => {
                                let (ks, kz) =
                                    (self.kscale[h * nt + tile], self.kzero[h * nt + tile]);
                                let (vs, vz) =
                                    (self.vscale[h * nt + tile], self.vzero[h * nt + tile]);
                                let src = (h * self.cap + tile * ps + r) * d / 2;
                                let half = d / 2;
                                dequantize_q4(
                                    &self.k4[src..src + half],
                                    ks,
                                    kz,
                                    &mut self.k[dst..dst + d],
                                );
                                dequantize_q4(
                                    &self.v4[src..src + half],
                                    vs,
                                    vz,
                                    &mut self.v[dst..dst + d],
                                );
                            }
                            KvDtype::F32 => unreachable!(),
                        }
                    }
                }
            }
            // else: the tile was already partial; rows [tile*ps, n) are a
            // prefix of what staging holds — nothing to restore
        }
        let page = (n - 1) / ps;
        if self.dtype.is_compressed() && tail == 0 {
            // tile-aligned boundary: the last page was complete before
            // truncation too, so its stored summary is already exact (and
            // its raw f32 rows no longer exist to rebuild from)
            return;
        }
        let p0 = page * ps;
        for h in 0..self.n_kv {
            let mut mins = vec![f32::INFINITY; d];
            let mut maxs = vec![f32::NEG_INFINITY; d];
            for pos in p0..n {
                let row = self.key(h, pos);
                for i in 0..d {
                    mins[i] = mins[i].min(row[i]);
                    maxs[i] = maxs[i].max(row[i]);
                }
            }
            let pb = ((h * self.cap.div_ceil(ps)) + page) * 2 * d;
            self.pages[pb..pb + d].copy_from_slice(&mins);
            self.pages[pb + d..pb + 2 * d].copy_from_slice(&maxs);
        }
    }
}

/// Work accounting for the cost-model side of Table 3 / Fig 8.
#[derive(Debug, Default, Clone, Copy)]
pub struct CostTracker {
    /// K rows read for score computation (dense or estimation passes).
    pub score_key_reads: u64,
    /// K/V rows read for the weighted-sum (output) computation.
    pub attend_kv_reads: u64,
    /// Entries pushed through top-k selection.
    pub topk_items: u64,
    /// Quantized KV rows read through the dequantizing attend path
    /// (value reads of int8 tiles).  Scoring over quantized keys is
    /// fused ([`crate::tensor::qk_dot_q8`]) and never counts here — the
    /// gap between `attend_kv_reads` and `dequant_rows` is exactly the
    /// work the Top-k selection saved from touching full precision.
    pub dequant_rows: u64,
}

impl CostTracker {
    pub fn merge(&mut self, o: &CostTracker) {
        self.score_key_reads += o.score_key_reads;
        self.attend_kv_reads += o.attend_kv_reads;
        self.topk_items += o.topk_items;
        self.dequant_rows += o.dequant_rows;
    }
}

// ---------------------------------------------------------------------------
// scratch arena
// ---------------------------------------------------------------------------

/// Per-KV-head index sets in one flat buffer (`idx`) with head offsets
/// (`offs`) — the allocation-free replacement for `Vec<Vec<u32>>`
/// selections.  Build with [`IndexSet::push`] + [`IndexSet::close_head`]
/// (or [`IndexSet::extend_head`]); buffers keep their capacity across
/// [`IndexSet::clear`], so steady-state reuse never allocates.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexSet {
    idx: Vec<u32>,
    /// head h spans `idx[offs[h]..offs[h+1]]`; `offs[0] == 0` always.
    offs: Vec<u32>,
}

impl Default for IndexSet {
    fn default() -> Self {
        Self::new()
    }
}

impl IndexSet {
    pub fn new() -> Self {
        Self { idx: Vec::new(), offs: vec![0] }
    }

    pub fn clear(&mut self) {
        self.idx.clear();
        self.offs.truncate(1);
    }

    /// Append one position to the head currently being built.
    #[inline]
    pub fn push(&mut self, p: u32) {
        self.idx.push(p);
    }

    /// Seal the head under construction (positions pushed since the last
    /// close).
    pub fn close_head(&mut self) {
        self.offs.push(self.idx.len() as u32);
    }

    /// Append one whole head from a slice.
    pub fn extend_head(&mut self, xs: &[u32]) {
        self.idx.extend_from_slice(xs);
        self.offs.push(self.idx.len() as u32);
    }

    pub fn n_heads(&self) -> usize {
        self.offs.len() - 1
    }

    #[inline]
    pub fn head(&self, h: usize) -> &[u32] {
        &self.idx[self.offs[h] as usize..self.offs[h + 1] as usize]
    }

    /// Total positions across all heads.
    pub fn total(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Overwrite with `o`'s contents, reusing this set's buffers.
    pub fn copy_from(&mut self, o: &IndexSet) {
        self.idx.clear();
        self.idx.extend_from_slice(&o.idx);
        self.offs.clear();
        self.offs.extend_from_slice(&o.offs);
    }

    /// Pre-size for `n_heads` heads of up to `per_head` positions each
    /// (the zero-allocation tests warm capacity through this).
    pub fn reserve(&mut self, n_heads: usize, per_head: usize) {
        self.idx.reserve(n_heads * per_head);
        self.offs.reserve(n_heads + 1);
    }

    /// Convenience for tests/benches: build from nested per-head vecs.
    pub fn from_nested(v: &[Vec<u32>]) -> Self {
        let mut s = Self::new();
        for h in v {
            s.extend_head(h);
        }
        s
    }

    /// Convenience for tests: explode back into nested per-head vecs.
    pub fn to_nested(&self) -> Vec<Vec<u32>> {
        (0..self.n_heads()).map(|h| self.head(h).to_vec()).collect()
    }
}

/// Reusable score/pooled planes and small staging buffers for the
/// attention kernels.  Kernels resize-on-demand but never shrink, so the
/// steady-state decode loop performs no heap allocations through these.
#[derive(Debug, Clone, Default)]
pub struct ScorePlanes {
    /// flat `[n_q, len]` per-query-head score planes (also the single-row
    /// staging buffer for kernels that score one row at a time)
    pub scores: Vec<f32>,
    /// flat `[pooled_heads, pooled_len]` pooled (per-KV-head) planes
    pub pooled: Vec<f32>,
    pooled_heads: usize,
    pooled_len: usize,
    /// quickselect partition staging ([`crate::tensor::topk_unordered_into`])
    pairs: Vec<(f32, u32)>,
    /// causally-kept index staging (prefill sparse tiles)
    kept: Vec<u32>,
    /// tile-own-coverage staging (prefill sparse tiles)
    own: Vec<bool>,
}

impl ScorePlanes {
    #[inline]
    fn ensure_scores(&mut self, n: usize) {
        if self.scores.len() < n {
            self.scores.resize(n, 0.0);
        }
    }

    #[inline]
    fn ensure_pooled(&mut self, n: usize) {
        if self.pooled.len() < n {
            self.pooled.resize(n, 0.0);
        }
    }

    /// Heads held by the most recent pooled-score kernel call.
    pub fn pooled_heads(&self) -> usize {
        self.pooled_heads
    }

    /// Plane length of the most recent pooled-score kernel call.
    pub fn pooled_len(&self) -> usize {
        self.pooled_len
    }

    /// Head `h`'s pooled distribution from the most recent pooled call.
    pub fn pooled_head(&self, h: usize) -> &[f32] {
        &self.pooled[h * self.pooled_len..(h + 1) * self.pooled_len]
    }

    /// Warm capacity for a model with `n_q`/`n_kv` heads and contexts up
    /// to `len` (zero-allocation tests call this once before measuring).
    pub fn reserve(&mut self, n_q: usize, n_kv: usize, len: usize) {
        self.ensure_scores(n_q * len);
        self.ensure_pooled(n_kv * len);
        self.pairs.reserve(len);
        self.kept.reserve(len);
        if self.own.len() < len {
            self.own.resize(len, false);
        }
    }
}

/// Per-sequence attention scratch arena: the current layer's sparse
/// selection (`sel`, written by [`crate::sparse::SparsePolicy`]
/// implementations) plus the kernel score planes.  Owned by
/// [`crate::model::SeqState`] and threaded through the policy trait and
/// the forward pass so the steady-state decode loop allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct AttnScratch {
    pub sel: IndexSet,
    pub planes: ScorePlanes,
}

impl AttnScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Warm every buffer to its steady-state capacity for a model with
    /// `n_q`/`n_kv` heads, contexts up to `len`, Top-k up to `k` — the
    /// zero-allocation tests call this once before measuring.
    pub fn reserve(&mut self, n_q: usize, n_kv: usize, len: usize, k: usize) {
        self.planes.reserve(n_q, n_kv, len);
        self.sel.reserve(n_kv, k.max(1));
    }
}

/// Scale for all scores: 1/sqrt(d).
#[inline]
fn scale(d: usize) -> f32 {
    1.0 / (d as f32).sqrt()
}

// ---------------------------------------------------------------------------
// decode attention
// ---------------------------------------------------------------------------

/// Dense decode attention for ONE KV head, clamped to the first `upto`
/// positions: the group's `g` query rows (`q` is the full `[n_q * d]`
/// row) attend over tiles via [`KvCache::score_tile`] /
/// [`KvCache::attend_tile`], writing the head's `[g * d]` output rows
/// into `out`.  This is the parallel engine's work-item granularity —
/// each `(sequence, head)` item is self-contained (own softmax, own
/// output rows), so sharding across workers is bitwise-order-free.
// analyze: hot-path
pub fn decode_dense_head(
    q: &[f32],
    h: usize,
    upto: usize,
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let len = upto.min(cache.len);
    let sc = scale(d);
    planes.ensure_scores(len);
    for qi in 0..g {
        let hq = h * g + qi;
        let qrow = &q[hq * d..(hq + 1) * d];
        let s = &mut planes.scores;
        let (mut t0, mut tile) = (0usize, 0usize);
        while t0 < len {
            t0 += cache.score_tile(h, tile, len, qrow, sc, &mut s[t0..]);
            tile += 1;
        }
        simd::softmax(cache.simd, &mut s[..len]);
        let orow = &mut out[qi * d..(qi + 1) * d];
        orow.fill(0.0);
        let (mut t0, mut tile) = (0usize, 0usize);
        while t0 < len {
            t0 += cache.attend_tile(h, tile, len, &s[t0..len], orow);
            tile += 1;
        }
    }
    cost.score_key_reads += (g * len) as u64;
    cost.attend_kv_reads += (g * len) as u64;
    if cache.is_quantized() {
        cost.dequant_rows += (g * len) as u64;
    }
}

/// Dense GQA decode attention.  `q` is `[n_q * d]` head-major, `out` too.
/// Attends to `cache.len` keys.  Tile-major: per tile the storage mode
/// and quantization params resolve once, then the inner loops run over
/// contiguous rows — bitwise-equal to the seed row-at-a-time kernel
/// ([`reference::decode_dense`]).
// analyze: hot-path
pub fn decode_dense(
    q: &[f32],
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let gd = g * cache.d;
    for h in 0..cache.n_kv {
        decode_dense_head(q, h, cache.len, cache, g, &mut out[h * gd..(h + 1) * gd], planes, cost);
    }
}

/// Per-query-head post-softmax distributions for one decode query,
/// written as flat `[n_q, len]` planes into `planes.scores`.
pub fn decode_head_scores(
    q: &[f32],
    cache: &KvCache,
    g: usize,
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let (d, len, n_kv) = (cache.d, cache.len, cache.n_kv);
    let n_q = n_kv * g;
    let sc = scale(d);
    planes.ensure_scores(n_q * len);
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            let s = &mut planes.scores[hq * len..(hq + 1) * len];
            let (mut t0, mut tile) = (0usize, 0usize);
            while t0 < len {
                t0 += cache.score_tile(h, tile, len, qrow, sc, &mut s[t0..]);
                tile += 1;
            }
            simd::softmax(cache.simd, s);
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
}

/// Mean-pool the `[n_q, len]` head planes into `[n_kv, len]` pooled
/// planes (groups of `g` consecutive rows), same accumulation order as
/// the seed `pool_groups`.
fn pool_groups_into(planes: &mut ScorePlanes, n_kv: usize, g: usize, len: usize) {
    let inv = 1.0 / g as f32;
    planes.ensure_pooled(n_kv * len);
    let ScorePlanes { scores, pooled, pooled_heads, pooled_len, .. } = planes;
    for h in 0..n_kv {
        let prow = &mut pooled[h * len..(h + 1) * len];
        prow.fill(0.0);
        for qi in 0..g {
            let srow = &scores[(h * g + qi) * len..(h * g + qi + 1) * len];
            for (pi, &x) in prow.iter_mut().zip(srow.iter()) {
                *pi += x * inv;
            }
        }
    }
    *pooled_heads = n_kv;
    *pooled_len = len;
}

/// GQA post-softmax pooling (paper Sec. 3.4, decode): mean of the group's
/// distributions per KV head, left in `planes` as `[n_kv, len]` pooled
/// planes (read via [`ScorePlanes::pooled_head`], consumed by
/// [`select_topk`]).
pub fn decode_pooled_scores(
    q: &[f32],
    cache: &KvCache,
    g: usize,
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    decode_head_scores(q, cache, g, planes, cost);
    pool_groups_into(planes, cache.n_kv, g, cache.len);
}

/// Pooled scores clamped to the first `upto` cache entries (used for
/// calibration probes at prefill positions).  Results land in `planes`
/// as `[n_kv, len]` pooled planes.
pub fn decode_pooled_scores_upto(
    q: &[f32],
    upto: usize,
    cache: &KvCache,
    g: usize,
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let len = upto.min(cache.len);
    let sc = scale(d);
    let inv = 1.0 / g as f32;
    planes.ensure_scores(len);
    planes.ensure_pooled(n_kv * len);
    let ScorePlanes { scores, pooled, pooled_heads, pooled_len, .. } = planes;
    for h in 0..n_kv {
        let prow = &mut pooled[h * len..(h + 1) * len];
        prow.fill(0.0);
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            let (mut t0, mut tile) = (0usize, 0usize);
            while t0 < len {
                t0 += cache.score_tile(h, tile, len, qrow, sc, &mut scores[t0..]);
                tile += 1;
            }
            simd::softmax(cache.simd, &mut scores[..len]);
            for (pi, &x) in prow.iter_mut().zip(scores[..len].iter()) {
                *pi += x * inv;
            }
        }
    }
    *pooled_heads = n_kv;
    *pooled_len = len;
    cost.score_key_reads += (n_kv * g * len) as u64;
}

/// Sparse decode attention for ONE KV head over an explicit index slice.
/// Per-query element sums are hoisted ([`KvCache::dot_key_with_sum`]);
/// index order is preserved so results stay bitwise-equal to the seed
/// kernel.
// analyze: hot-path
pub fn decode_sparse_head(
    q: &[f32],
    h: usize,
    idx: &[u32],
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let sc = scale(d);
    let m = idx.len();
    planes.ensure_scores(m);
    for qi in 0..g {
        let hq = h * g + qi;
        let qrow = &q[hq * d..(hq + 1) * d];
        let q_sum = simd::sum4(cache.simd, qrow);
        let s = &mut planes.scores;
        for (j, &p) in idx.iter().enumerate() {
            s[j] = cache.dot_key_with_sum(h, p as usize, qrow, q_sum) * sc;
        }
        simd::softmax(cache.simd, &mut s[..m]);
        let orow = &mut out[qi * d..(qi + 1) * d];
        orow.fill(0.0);
        for (j, &p) in idx.iter().enumerate() {
            if s[j] > 1e-9 {
                cache.add_val(h, p as usize, s[j], orow);
            }
        }
    }
    cost.score_key_reads += (g * m) as u64;
    cost.attend_kv_reads += (g * m) as u64;
    if cache.is_quantized() {
        cost.dequant_rows += (g * m) as u64;
    }
}

/// Sparse decode attention over per-KV-head index sets.
// analyze: hot-path
pub fn decode_sparse(
    q: &[f32],
    cache: &KvCache,
    g: usize,
    sel: &IndexSet,
    out: &mut [f32],
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let gd = g * cache.d;
    for h in 0..sel.n_heads() {
        let out_h = &mut out[h * gd..(h + 1) * gd];
        decode_sparse_head(q, h, sel.head(h), cache, g, out_h, planes, cost);
    }
}

// ---------------------------------------------------------------------------
// prefill attention (tile-based)
// ---------------------------------------------------------------------------

/// Dense causal prefill attention for a tile of queries.
///
/// `qs` is `[tile, n_q * d]`; query row `r` sits at absolute position
/// `start + r` and attends to keys `[0, start + r]` (the cache must already
/// contain the tile's own keys).  `out` is `[tile, n_q * d]`.
pub fn prefill_dense_tile(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let n_q = cache.n_kv * g;
    let tile = qs.len() / (n_q * d);
    for r in 0..tile {
        decode_dense_upto(
            &qs[r * n_q * d..(r + 1) * n_q * d],
            start + r + 1,
            cache,
            g,
            &mut out[r * n_q * d..(r + 1) * n_q * d],
            planes,
            cost,
        );
    }
}

/// Dense decode attention clamped to the first `upto` cache entries.
pub fn decode_dense_upto(
    q: &[f32],
    upto: usize,
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let gd = g * cache.d;
    for h in 0..cache.n_kv {
        decode_dense_head(q, h, upto, cache, g, &mut out[h * gd..(h + 1) * gd], planes, cost);
    }
}

/// Tile-level post-softmax pooled scores for prefill (anchor passes 1+2):
/// the mean over (GQA group x tile rows) of each query's causal
/// post-softmax distribution, per KV head: `[n_kv][kv_len]` where
/// `kv_len = start + tile`.
pub fn prefill_pooled_scores(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let n_q = n_kv * g;
    let tile = qs.len() / (n_q * d);
    let kv_len = (start + tile).min(cache.len);
    let sc = scale(d);
    let inv = 1.0 / (tile * g) as f32;
    // causal triangular work: row r reads min(start + r + 1, kv_len) keys
    // per (head, group) query — NOT tile * kv_len (Fig. 8 / Table 3 cost
    // ratios were overcounting the anchor pass before this was fixed)
    let row_reads: u64 = (0..tile).map(|r| (start + r + 1).min(kv_len) as u64).sum();
    planes.ensure_scores(kv_len);
    planes.ensure_pooled(n_kv * kv_len);
    let ScorePlanes { scores, pooled, pooled_heads, pooled_len, .. } = planes;
    for h in 0..n_kv {
        let prow = &mut pooled[h * kv_len..(h + 1) * kv_len];
        prow.fill(0.0);
        for r in 0..tile {
            let upto = (start + r + 1).min(kv_len);
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                let (mut t0, mut ti) = (0usize, 0usize);
                while t0 < upto {
                    t0 += cache.score_tile(h, ti, upto, qrow, sc, &mut scores[t0..]);
                    ti += 1;
                }
                simd::softmax(cache.simd, &mut scores[..upto]);
                for (pi, &x) in prow[..upto].iter_mut().zip(scores[..upto].iter()) {
                    *pi += x * inv;
                }
            }
        }
        cost.score_key_reads += g as u64 * row_reads;
    }
    *pooled_heads = n_kv;
    *pooled_len = kv_len;
}

/// Sparse prefill attention for a tile with tile-shared indices and
/// per-query causal clamping (paper Sec. 3.4 / 4.1 rolling Top-k).
// analyze: hot-path
pub fn prefill_sparse_tile(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    sel: &IndexSet,
    out: &mut [f32],
    planes: &mut ScorePlanes,
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let n_q = cache.n_kv * g;
    let tile = qs.len() / (n_q * d);
    let sc = scale(d);
    for r in 0..tile {
        let qpos = start + r;
        for h in 0..sel.n_heads() {
            let hidx = sel.head(h);
            let ScorePlanes { scores, kept, own, .. } = &mut *planes;
            kept.clear();
            // which of the tile's own (causally visible) positions the
            // index set already covers: offset j <=> position start + j
            own.clear();
            own.resize(r + 1, false);
            for &p in hidx {
                if (p as usize) <= qpos {
                    // analyze: allow(hot-path-alloc) — arena scratch vec; capacity persists across tiles
                    kept.push(p);
                    if (p as usize) >= start {
                        own[p as usize - start] = true;
                    }
                }
            }
            // rolling-Top-k guarantee (paper Sec. 4.1): a tile's own
            // positions are always visible to its queries, even when the
            // anchor's indices all land in this query's causal future
            for (j, seen) in own.iter().enumerate() {
                if !seen {
                    // analyze: allow(hot-path-alloc) — arena scratch vec; capacity persists across tiles
                    kept.push((start + j) as u32);
                }
            }
            let m = kept.len();
            if scores.len() < m {
                scores.resize(m, 0.0);
            }
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                let q_sum = simd::sum4(cache.simd, qrow);
                for (j, &p) in kept.iter().enumerate() {
                    scores[j] = cache.dot_key_with_sum(h, p as usize, qrow, q_sum) * sc;
                }
                simd::softmax(cache.simd, &mut scores[..m]);
                let orow = &mut out[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                orow.fill(0.0);
                for (j, &p) in kept.iter().enumerate() {
                    if scores[j] > 1e-9 {
                        cache.add_val(h, p as usize, scores[j], orow);
                    }
                }
            }
            cost.score_key_reads += (g * m) as u64;
            cost.attend_kv_reads += (g * m) as u64;
            if cache.is_quantized() {
                cost.dequant_rows += (g * m) as u64;
            }
        }
    }
}

/// Top-k over the pooled planes left in `scratch.planes` by the last
/// pooled-score kernel call (anchor pass 3), written into `scratch.sel`
/// as one head per pooled plane.  Uses the O(n) unordered quickselect —
/// attention is order-invariant over the index set — staged in the
/// arena's partition buffer through [`simd::topk_into`] (the staging
/// fill is the lane-parallel phase; the swap chain stays scalar), so the
/// steady-state call allocates nothing and selects the exact same
/// indices at every vector level.
pub fn select_topk(scratch: &mut AttnScratch, k: usize, cost: &mut CostTracker) {
    let lv = simd::detect();
    let AttnScratch { sel, planes } = scratch;
    let (hn, len) = (planes.pooled_heads, planes.pooled_len);
    sel.clear();
    let ScorePlanes { pooled, pairs, .. } = planes;
    for h in 0..hn {
        cost.topk_items += len as u64;
        simd::topk_into(lv, &pooled[h * len..(h + 1) * len], k.min(len), pairs, &mut sel.idx);
        sel.close_head();
    }
}

// ---------------------------------------------------------------------------
// seed kernels (reference implementations)
// ---------------------------------------------------------------------------

/// The seed row-at-a-time kernels, kept verbatim as the ground truth the
/// tile-major/arena kernels are bitwise-tested against (and as the
/// baseline side of the kernel-level benches in
/// `benches/table3_kernels.rs`).  Every call re-dispatches on the storage
/// mode per position and heap-allocates its score buffers — exactly the
/// overheads the tile-major path removes.
pub mod reference {
    use super::{scale, CostTracker, KvCache};
    use crate::tensor::{softmax, topk_indices_unordered};

    /// Seed dense GQA decode attention.
    pub fn decode_dense(
        q: &[f32],
        cache: &KvCache,
        g: usize,
        out: &mut [f32],
        cost: &mut CostTracker,
    ) {
        decode_dense_upto(q, cache.len, cache, g, out, cost);
    }

    /// Seed dense decode attention clamped to the first `upto` entries.
    pub fn decode_dense_upto(
        q: &[f32],
        upto: usize,
        cache: &KvCache,
        g: usize,
        out: &mut [f32],
        cost: &mut CostTracker,
    ) {
        let (d, n_kv) = (cache.d, cache.n_kv);
        let len = upto.min(cache.len);
        let sc = scale(d);
        let mut s = vec![0.0f32; len];
        for h in 0..n_kv {
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &q[hq * d..(hq + 1) * d];
                for p in 0..len {
                    s[p] = cache.dot_key(h, p, qrow) * sc;
                }
                softmax(&mut s);
                let orow = &mut out[hq * d..(hq + 1) * d];
                orow.fill(0.0);
                for p in 0..len {
                    if s[p] > 1e-9 {
                        cache.add_val(h, p, s[p], orow);
                    }
                }
            }
        }
        cost.score_key_reads += (n_kv * g * len) as u64;
        cost.attend_kv_reads += (n_kv * g * len) as u64;
        if cache.is_quantized() {
            cost.dequant_rows += (n_kv * g * len) as u64;
        }
    }

    /// Seed per-query-head post-softmax distributions: `[n_q][len]`.
    pub fn decode_head_scores(
        q: &[f32],
        cache: &KvCache,
        g: usize,
        cost: &mut CostTracker,
    ) -> Vec<Vec<f32>> {
        let (d, len, n_kv) = (cache.d, cache.len, cache.n_kv);
        let sc = scale(d);
        let mut all = Vec::with_capacity(n_kv * g);
        for h in 0..n_kv {
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &q[hq * d..(hq + 1) * d];
                let mut s = vec![0.0f32; len];
                for p in 0..len {
                    s[p] = cache.dot_key(h, p, qrow) * sc;
                }
                softmax(&mut s);
                all.push(s);
            }
        }
        cost.score_key_reads += (n_kv * g * len) as u64;
        all
    }

    /// Seed mean-pool of groups of `g` consecutive distributions.
    pub fn pool_groups(per_head: &[Vec<f32>], g: usize) -> Vec<Vec<f32>> {
        let n_kv = per_head.len() / g;
        let len = per_head[0].len();
        let inv = 1.0 / g as f32;
        (0..n_kv)
            .map(|h| {
                let mut p = vec![0.0f32; len];
                for qi in 0..g {
                    for (pi, &x) in p.iter_mut().zip(per_head[h * g + qi].iter()) {
                        *pi += x * inv;
                    }
                }
                p
            })
            .collect()
    }

    /// Seed GQA pooled scores: `[n_kv][len]`.
    pub fn decode_pooled_scores(
        q: &[f32],
        cache: &KvCache,
        g: usize,
        cost: &mut CostTracker,
    ) -> Vec<Vec<f32>> {
        let per_head = decode_head_scores(q, cache, g, cost);
        pool_groups(&per_head, g)
    }

    /// Seed sparse decode attention over nested per-KV-head index sets.
    pub fn decode_sparse(
        q: &[f32],
        cache: &KvCache,
        g: usize,
        idx: &[Vec<u32>],
        out: &mut [f32],
        cost: &mut CostTracker,
    ) {
        let d = cache.d;
        let sc = scale(d);
        let mut total = 0u64;
        for (h, hidx) in idx.iter().enumerate() {
            let mut s = vec![0.0f32; hidx.len()];
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &q[hq * d..(hq + 1) * d];
                for (j, &p) in hidx.iter().enumerate() {
                    s[j] = cache.dot_key(h, p as usize, qrow) * sc;
                }
                softmax(&mut s);
                let orow = &mut out[hq * d..(hq + 1) * d];
                orow.fill(0.0);
                for (j, &p) in hidx.iter().enumerate() {
                    if s[j] > 1e-9 {
                        cache.add_val(h, p as usize, s[j], orow);
                    }
                }
            }
            total += (g * hidx.len()) as u64;
        }
        cost.score_key_reads += total;
        cost.attend_kv_reads += total;
        if cache.is_quantized() {
            cost.dequant_rows += total;
        }
    }

    /// Seed dense causal prefill for a tile of queries.
    pub fn prefill_dense_tile(
        qs: &[f32],
        start: usize,
        cache: &KvCache,
        g: usize,
        out: &mut [f32],
        cost: &mut CostTracker,
    ) {
        let d = cache.d;
        let n_q = cache.n_kv * g;
        let tile = qs.len() / (n_q * d);
        for r in 0..tile {
            decode_dense_upto(
                &qs[r * n_q * d..(r + 1) * n_q * d],
                start + r + 1,
                cache,
                g,
                &mut out[r * n_q * d..(r + 1) * n_q * d],
                cost,
            );
        }
    }

    /// Seed tile-level pooled prefill scores: `[n_kv][kv_len]`.
    pub fn prefill_pooled_scores(
        qs: &[f32],
        start: usize,
        cache: &KvCache,
        g: usize,
        cost: &mut CostTracker,
    ) -> Vec<Vec<f32>> {
        let (d, n_kv) = (cache.d, cache.n_kv);
        let n_q = n_kv * g;
        let tile = qs.len() / (n_q * d);
        let kv_len = (start + tile).min(cache.len);
        let sc = scale(d);
        let inv = 1.0 / (tile * g) as f32;
        let row_reads: u64 = (0..tile).map(|r| (start + r + 1).min(kv_len) as u64).sum();
        let mut pooled = vec![vec![0.0f32; kv_len]; n_kv];
        let mut s = vec![0.0f32; kv_len];
        for h in 0..n_kv {
            for r in 0..tile {
                let upto = (start + r + 1).min(kv_len);
                for qi in 0..g {
                    let hq = h * g + qi;
                    let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                    for p in 0..upto {
                        s[p] = cache.dot_key(h, p, qrow) * sc;
                    }
                    softmax(&mut s[..upto]);
                    for p in 0..upto {
                        pooled[h][p] += s[p] * inv;
                    }
                }
            }
            cost.score_key_reads += g as u64 * row_reads;
        }
        pooled
    }

    /// Seed sparse prefill for a tile with tile-shared nested indices.
    pub fn prefill_sparse_tile(
        qs: &[f32],
        start: usize,
        cache: &KvCache,
        g: usize,
        idx: &[Vec<u32>],
        out: &mut [f32],
        cost: &mut CostTracker,
    ) {
        let d = cache.d;
        let n_q = cache.n_kv * g;
        let tile = qs.len() / (n_q * d);
        let sc = scale(d);
        for r in 0..tile {
            let qpos = start + r;
            for (h, hidx) in idx.iter().enumerate() {
                let mut s = Vec::with_capacity(hidx.len() + r + 1);
                let mut kept: Vec<u32> = Vec::with_capacity(hidx.len() + r + 1);
                let mut own = vec![false; r + 1];
                for &p in hidx {
                    if (p as usize) <= qpos {
                        kept.push(p);
                        if (p as usize) >= start {
                            own[p as usize - start] = true;
                        }
                    }
                }
                for (j, seen) in own.iter().enumerate() {
                    if !seen {
                        kept.push((start + j) as u32);
                    }
                }
                for qi in 0..g {
                    let hq = h * g + qi;
                    let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                    s.clear();
                    for &p in &kept {
                        s.push(cache.dot_key(h, p as usize, qrow) * sc);
                    }
                    softmax(&mut s);
                    let orow = &mut out[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                    orow.fill(0.0);
                    for (j, &p) in kept.iter().enumerate() {
                        if s[j] > 1e-9 {
                            cache.add_val(h, p as usize, s[j], orow);
                        }
                    }
                }
                cost.score_key_reads += (g * kept.len()) as u64;
                cost.attend_kv_reads += (g * kept.len()) as u64;
                if cache.is_quantized() {
                    cost.dequant_rows += (g * kept.len()) as u64;
                }
            }
        }
    }

    /// Seed Top-k over nested pooled scores.
    pub fn select_topk(pooled: &[Vec<f32>], k: usize, cost: &mut CostTracker) -> Vec<Vec<u32>> {
        pooled
            .iter()
            .map(|p| {
                cost.topk_items += p.len() as u64;
                topk_indices_unordered(p, k.min(p.len()))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(n_kv: usize, g: usize, d: usize, len: usize, seed: u64) -> (Vec<f32>, KvCache) {
        let mut r = Rng::new(seed);
        let n_q = n_kv * g;
        let mut q = vec![0.0; n_q * d];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(n_kv, d, len + 8);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        (q, cache)
    }

    #[test]
    fn dense_decode_is_convex_combination() {
        let (q, cache) = setup(2, 2, 16, 64, 1);
        let mut out = vec![0.0; 4 * 16];
        let mut c = CostTracker::default();
        let mut planes = ScorePlanes::default();
        decode_dense(&q, &cache, 2, &mut out, &mut planes, &mut c);
        // bounded by value hull per kv head
        for h in 0..2 {
            let mut vmax = f32::NEG_INFINITY;
            let mut vmin = f32::INFINITY;
            for p in 0..64 {
                for &x in cache.val(h, p) {
                    vmax = vmax.max(x);
                    vmin = vmin.min(x);
                }
            }
            for qi in 0..2 {
                for &x in &out[(h * 2 + qi) * 16..(h * 2 + qi + 1) * 16] {
                    assert!(x <= vmax + 1e-4 && x >= vmin - 1e-4);
                }
            }
        }
        assert_eq!(c.score_key_reads, 4 * 64);
    }

    #[test]
    fn sparse_with_all_indices_equals_dense() {
        let (q, cache) = setup(2, 2, 16, 64, 2);
        let mut dense = vec![0.0; 4 * 16];
        let mut sparse = vec![0.0; 4 * 16];
        let mut c = CostTracker::default();
        let mut planes = ScorePlanes::default();
        decode_dense(&q, &cache, 2, &mut dense, &mut planes, &mut c);
        let sel = IndexSet::from_nested(&[(0..64).collect(), (0..64).collect()]);
        decode_sparse(&q, &cache, 2, &sel, &mut sparse, &mut planes, &mut c);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn pooled_scores_are_distributions() {
        let (q, cache) = setup(2, 2, 16, 64, 3);
        let mut c = CostTracker::default();
        let mut planes = ScorePlanes::default();
        decode_pooled_scores(&q, &cache, 2, &mut planes, &mut c);
        assert_eq!(planes.pooled_heads(), 2);
        assert_eq!(planes.pooled_len(), 64);
        for h in 0..2 {
            let sum: f32 = planes.pooled_head(h).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn index_set_round_trips_nested() {
        let nested = vec![vec![3u32, 1, 7], vec![], vec![9u32]];
        let sel = IndexSet::from_nested(&nested);
        assert_eq!(sel.n_heads(), 3);
        assert_eq!(sel.total(), 4);
        assert_eq!(sel.head(0), &[3, 1, 7]);
        assert!(sel.head(1).is_empty());
        assert_eq!(sel.to_nested(), nested);
        let mut other = IndexSet::new();
        other.copy_from(&sel);
        assert_eq!(other, sel);
        other.clear();
        assert_eq!(other.n_heads(), 0);
        assert!(other.is_empty());
    }

    #[test]
    fn topk_sparse_approximates_dense_when_peaked() {
        // make one key align strongly with the query
        let mut r = Rng::new(4);
        let (n_kv, g, d, len) = (2, 2, 16, 128);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(n_kv, d, len);
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.2);
            r.fill_normal(&mut v, 1.0);
            if p == 77 {
                // strong alignment for every (kv, q) pair
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 2.0;
                    }
                }
            }
            cache.push(&k, &v);
        }
        let mut c = CostTracker::default();
        let mut scratch = AttnScratch::new();
        decode_pooled_scores(&q, &cache, g, &mut scratch.planes, &mut c);
        select_topk(&mut scratch, 16, &mut c);
        assert!((0..scratch.sel.n_heads()).all(|h| scratch.sel.head(h).contains(&77)));
        let mut dense = vec![0.0; n_kv * g * d];
        let mut sparse = vec![0.0; n_kv * g * d];
        let AttnScratch { sel, planes } = &mut scratch;
        decode_dense(&q, &cache, g, &mut dense, planes, &mut c);
        decode_sparse(&q, &cache, g, sel, &mut sparse, planes, &mut c);
        let cos = crate::tensor::cosine_sim(&dense, &sparse);
        assert!(cos > 0.9, "cos {cos}");
    }

    #[test]
    fn prefill_dense_tile_matches_per_token_decode() {
        let mut r = Rng::new(5);
        let (n_kv, g, d, len) = (2, 2, 8, 32);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, len);
        let mut qs = vec![0.0; len * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut c = CostTracker::default();
        let mut planes = ScorePlanes::default();
        let mut tile_out = vec![0.0; len * n_q * d];
        prefill_dense_tile(&qs, 0, &cache, g, &mut tile_out, &mut planes, &mut c);
        for t in 0..len {
            let mut want = vec![0.0; n_q * d];
            let q_t = &qs[t * n_q * d..(t + 1) * n_q * d];
            decode_dense_upto(q_t, t + 1, &cache, g, &mut want, &mut planes, &mut c);
            for (a, b) in tile_out[t * n_q * d..(t + 1) * n_q * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prefill_pooled_rows_sum_to_one() {
        let mut r = Rng::new(6);
        let (n_kv, g, d, tile) = (2, 2, 8, 16);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 64);
        for _ in 0..48 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut c = CostTracker::default();
        let mut planes = ScorePlanes::default();
        prefill_pooled_scores(&qs, 32, &cache, g, &mut planes, &mut c);
        assert_eq!(planes.pooled_len(), 48);
        for h in 0..planes.pooled_heads() {
            let sum: f32 = planes.pooled_head(h).iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "sum {sum}");
        }
    }

    #[test]
    fn prefill_sparse_clamps_future_indices() {
        let mut r = Rng::new(7);
        let (n_kv, g, d, tile) = (1, 2, 8, 8);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 16);
        for _ in 0..8 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        // indices include every position; query 0 may only use position 0
        let sel = IndexSet::from_nested(&[(0..8u32).collect::<Vec<_>>()]);
        let mut out = vec![0.0; tile * n_q * d];
        let mut c = CostTracker::default();
        let mut planes = ScorePlanes::default();
        prefill_sparse_tile(&qs, 0, &cache, g, &sel, &mut out, &mut planes, &mut c);
        for hq in 0..n_q {
            for i in 0..d {
                assert!((out[hq * d + i] - cache.val(0, 0)[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prefill_sparse_always_sees_tile_own_positions() {
        // all anchor indices land in the tile's future: every query must
        // still see the tile's own causally-visible range (Sec. 4.1), not
        // collapse to self-only attention
        let mut r = Rng::new(12);
        let (n_kv, g, d, tile, start) = (1usize, 2usize, 8usize, 8usize, 8usize);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 16);
        for _ in 0..16 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        // anchor indices all at the end of the tile (future for early rows)
        let sel = IndexSet::from_nested(&[vec![12u32, 13, 14, 15]]);
        let mut out = vec![0.0; tile * n_q * d];
        let mut c = CostTracker::default();
        let mut planes = ScorePlanes::default();
        prefill_sparse_tile(&qs, start, &cache, g, &sel, &mut out, &mut planes, &mut c);
        for row in 0..tile {
            let qpos = start + row;
            // expected: attention over the union {idx <= qpos} u {start..=qpos},
            // which here is exactly the tile's own visible range
            let expect = IndexSet::from_nested(&[(start as u32..=qpos as u32).collect()]);
            let mut want = vec![0.0; n_q * d];
            decode_sparse(
                &qs[row * n_q * d..(row + 1) * n_q * d],
                &cache,
                g,
                &expect,
                &mut want,
                &mut planes,
                &mut CostTracker::default(),
            );
            for (a, b) in out[row * n_q * d..(row + 1) * n_q * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "row {row}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn prefill_pooled_cost_matches_dense_tile_pass() {
        // the anchor estimation pass reads exactly the causal triangle of
        // keys — its accounted cost must equal the dense tile pass's
        let mut r = Rng::new(13);
        let (n_kv, g, d, tile, start) = (2usize, 2usize, 8usize, 16usize, 32usize);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 64);
        for _ in 0..48 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut planes = ScorePlanes::default();
        let mut c_pool = CostTracker::default();
        prefill_pooled_scores(&qs, start, &cache, g, &mut planes, &mut c_pool);
        let mut c_dense = CostTracker::default();
        let mut out = vec![0.0; tile * n_q * d];
        prefill_dense_tile(&qs, start, &cache, g, &mut out, &mut planes, &mut c_dense);
        assert_eq!(c_pool.score_key_reads, c_dense.score_key_reads);
        // triangular sum, explicitly: sum_r min(start + r + 1, kv_len)
        let want: u64 = (0..tile).map(|r| (start + r + 1).min(48) as u64).sum();
        assert_eq!(c_pool.score_key_reads, (n_kv * g) as u64 * want);
    }

    #[test]
    fn page_summaries_bound_keys() {
        let (_, cache) = setup(2, 2, 16, 70, 8);
        for h in 0..2 {
            for page in 0..cache.n_pages() {
                let (mins, maxs) = cache.page_summary(h, page);
                let lo = page * cache.page_size();
                let hi = ((page + 1) * cache.page_size()).min(cache.len);
                for p in lo..hi {
                    for (i, &x) in cache.key(h, p).iter().enumerate() {
                        assert!(x >= mins[i] - 1e-6 && x <= maxs[i] + 1e-6);
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn cache_overflow_panics() {
        let mut cache = KvCache::new(1, 4, 2);
        let k = vec![0.0; 4];
        for _ in 0..3 {
            cache.push(&k, &k);
        }
    }

    /// Build an f32 cache and a `dtype` cache holding identical pushes.
    fn paired_caches_d(
        n_kv: usize,
        d: usize,
        len: usize,
        seed: u64,
        dtype: crate::config::KvDtype,
    ) -> (KvCache, KvCache) {
        let mut r = Rng::new(seed);
        let mut cf = KvCache::new(n_kv, d, len + 8);
        let mut cq = KvCache::with_opts(n_kv, d, len + 8, 16, dtype);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cf.push(&k, &v);
            cq.push(&k, &v);
        }
        (cf, cq)
    }

    /// Build an f32 cache and an int8 cache holding identical pushes.
    fn paired_caches(n_kv: usize, d: usize, len: usize, seed: u64) -> (KvCache, KvCache) {
        paired_caches_d(n_kv, d, len, seed, crate::config::KvDtype::Int8)
    }

    #[test]
    fn int8_dense_decode_close_to_f32() {
        let mut r = Rng::new(41);
        let (n_kv, g, d, len) = (2, 2, 16, 200);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let (cf, cq) = paired_caches(n_kv, d, len, 42);
        let mut of = vec![0.0; n_kv * g * d];
        let mut oq = vec![0.0; n_kv * g * d];
        let mut planes = ScorePlanes::default();
        let mut c = CostTracker::default();
        decode_dense(&q, &cf, g, &mut of, &mut planes, &mut c);
        let mut c8 = CostTracker::default();
        decode_dense(&q, &cq, g, &mut oq, &mut planes, &mut c8);
        let cos = crate::tensor::cosine_sim(&of, &oq);
        assert!(cos > 0.999, "cos {cos}");
        assert!(c8.dequant_rows > 0, "dense fallback must dequantize");
        assert_eq!(c.dequant_rows, 0, "f32 never dequantizes");
    }

    #[test]
    fn int8_pooled_scores_close_and_fused() {
        let mut r = Rng::new(43);
        let (n_kv, g, d, len) = (2, 2, 16, 200);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let (cf, cq) = paired_caches(n_kv, d, len, 44);
        let mut c = CostTracker::default();
        let mut pf = ScorePlanes::default();
        decode_pooled_scores(&q, &cf, g, &mut pf, &mut c);
        let mut c8 = CostTracker::default();
        let mut pq = ScorePlanes::default();
        decode_pooled_scores(&q, &cq, g, &mut pq, &mut c8);
        assert_eq!(c8.dequant_rows, 0, "scoring is fused over int8 — no dequant");
        for h in 0..n_kv {
            for (x, y) in pf.pooled_head(h).iter().zip(pq.pooled_head(h)) {
                assert!((x - y).abs() < 5e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn int8_kv_bytes_shrink() {
        let (cf, cq) = paired_caches(2, 16, 200, 45);
        let (bf, bq) = (cf.kv_bytes(), cq.kv_bytes());
        let ratio = bf as f64 / bq as f64;
        assert!(ratio >= 1.8, "bytes ratio {ratio:.2} (f32 {bf} int8 {bq})");
    }

    #[test]
    fn f16_dense_decode_tight_and_never_dequants() {
        let mut r = Rng::new(51);
        let (n_kv, g, d, len) = (2, 2, 16, 200);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let (cf, ch) = paired_caches_d(n_kv, d, len, 52, crate::config::KvDtype::F16);
        let mut of = vec![0.0; n_kv * g * d];
        let mut oh = vec![0.0; n_kv * g * d];
        let mut planes = ScorePlanes::default();
        let mut c = CostTracker::default();
        decode_dense(&q, &cf, g, &mut of, &mut planes, &mut c);
        let mut ch_cost = CostTracker::default();
        decode_dense(&q, &ch, g, &mut oh, &mut planes, &mut ch_cost);
        // f16 keeps ~11 bits of mantissa: far tighter than int8's 0.999
        let cos = crate::tensor::cosine_sim(&of, &oh);
        assert!(cos > 0.999_99, "cos {cos}");
        assert_eq!(ch_cost.dequant_rows, 0, "f16 reads are conversions, not dequants");
        assert!(!ch.is_quantized());
    }

    #[test]
    fn int4_dense_decode_close_to_f32() {
        let mut r = Rng::new(53);
        let (n_kv, g, d, len) = (2, 2, 16, 200);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let (cf, c4) = paired_caches_d(n_kv, d, len, 54, crate::config::KvDtype::Int4);
        let mut of = vec![0.0; n_kv * g * d];
        let mut o4 = vec![0.0; n_kv * g * d];
        let mut planes = ScorePlanes::default();
        let mut c = CostTracker::default();
        decode_dense(&q, &cf, g, &mut of, &mut planes, &mut c);
        let mut c4_cost = CostTracker::default();
        decode_dense(&q, &c4, g, &mut o4, &mut planes, &mut c4_cost);
        // 4-bit codes: coarser than int8 but still directionally faithful
        let cos = crate::tensor::cosine_sim(&of, &o4);
        assert!(cos > 0.99, "cos {cos}");
        assert!(c4_cost.dequant_rows > 0, "int4 attend dequantizes");
        assert!(c4.is_quantized());
    }

    #[test]
    fn f16_and_int4_kv_bytes_shrink() {
        let (cf, ch) = paired_caches_d(2, 16, 200, 55, crate::config::KvDtype::F16);
        let rh = cf.kv_bytes() as f64 / ch.kv_bytes() as f64;
        assert!(rh >= 1.7, "f16 bytes ratio {rh:.2}");
        let (_, c4) = paired_caches_d(2, 16, 200, 55, crate::config::KvDtype::Int4);
        let r4 = cf.kv_bytes() as f64 / c4.kv_bytes() as f64;
        assert!(r4 >= 3.0, "int4 bytes ratio {r4:.2}");
        // strict ordering: narrower dtype, fewer resident bytes
        assert!(c4.kv_bytes() < ch.kv_bytes());
    }

    #[test]
    fn compressed_staged_tail_is_exact_f32() {
        for dtype in [crate::config::KvDtype::F16, crate::config::KvDtype::Int4] {
            // 2 full tiles + 9 staged positions
            let (cf, cq) = paired_caches_d(2, 8, 41, 56, dtype);
            for h in 0..2 {
                for p in 32..41 {
                    assert_eq!(cf.key(h, p), cq.key(h, p), "{dtype:?}");
                    assert_eq!(cf.val(h, p), cq.val(h, p), "{dtype:?}");
                    assert!(cq.f16_key_row(h, p).is_none());
                    assert!(cq.packed_key_row(h, p).is_none());
                }
                match dtype {
                    crate::config::KvDtype::F16 => {
                        assert!(cq.f16_key_row(h, 31).is_some());
                    }
                    crate::config::KvDtype::Int4 => {
                        let (codes, _, _) = cq.packed_key_row(h, 31).unwrap();
                        assert_eq!(codes.len(), 4); // d/2 packed bytes
                    }
                    _ => unreachable!(),
                }
            }
        }
    }

    #[test]
    fn compressed_truncate_mid_tile_restores_staging() {
        for (dtype, tol) in [
            (crate::config::KvDtype::F16, 5e-3f32),
            (crate::config::KvDtype::Int4, 5e-1f32),
        ] {
            let (_, mut cq) = paired_caches_d(2, 8, 48, 57, dtype); // 3 full tiles
            let probe_q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.31).sin()).collect();
            let before: Vec<f32> = (0..23).map(|p| cq.dot_key(1, p, &probe_q)).collect();
            cq.truncate(23); // mid-tile boundary inside full tile 1
            assert_eq!(cq.len, 23);
            let after: Vec<f32> = (0..23).map(|p| cq.dot_key(1, p, &probe_q)).collect();
            // full tile 0 untouched (bitwise); restored rows within the
            // mode's conversion error
            for (p, (a, b)) in before.iter().zip(&after).enumerate() {
                if p < 16 {
                    assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} pos {p}");
                } else {
                    assert!((a - b).abs() < tol, "{dtype:?} pos {p}: {a} vs {b}");
                }
            }
            // refilling re-completes the tail tile without panicking
            let k = vec![0.25; 2 * 8];
            for _ in 0..12 {
                cq.push(&k, &k);
            }
            assert_eq!(cq.len, 35);
            match dtype {
                crate::config::KvDtype::F16 => assert!(cq.f16_key_row(0, 17).is_some()),
                crate::config::KvDtype::Int4 => assert!(cq.packed_key_row(0, 17).is_some()),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn set_simd_level_round_trips() {
        let mut cache = KvCache::new(1, 8, 32);
        assert_eq!(cache.simd_level(), crate::simd::detect());
        cache.set_simd_level(SimdLevel::Scalar);
        assert_eq!(cache.simd_level(), SimdLevel::Scalar);
    }

    #[test]
    fn int8_staged_tail_is_exact_f32() {
        // positions past the last full tile are staged — identical reads
        let (cf, cq) = paired_caches(2, 8, 41, 46); // 2 full tiles + 9 staged
        for h in 0..2 {
            for p in 32..41 {
                assert_eq!(cf.key(h, p), cq.key(h, p));
                assert_eq!(cf.val(h, p), cq.val(h, p));
                assert!(cq.quantized_key_row(h, p).is_none());
            }
            assert!(cq.quantized_key_row(h, 31).is_some());
        }
    }

    #[test]
    fn int8_truncate_mid_tile_restores_staging() {
        // truncate into a completed tile, then refill: reads must match a
        // cache that was never truncated past that point (up to the one
        // dequant/requant round-trip, which is deterministic)
        let (_, mut cq) = paired_caches(2, 8, 48, 47); // 3 full tiles
        let probe_q: Vec<f32> = (0..8).map(|i| (i as f32 * 0.31).sin()).collect();
        let before: Vec<f32> = (0..23).map(|p| cq.dot_key(1, p, &probe_q)).collect();
        cq.truncate(23); // mid-tile boundary inside full tile 1
        assert_eq!(cq.len, 23);
        let after: Vec<f32> = (0..23).map(|p| cq.dot_key(1, p, &probe_q)).collect();
        // full tile 0 untouched (bitwise); restored rows within quant error
        for (p, (a, b)) in before.iter().zip(&after).enumerate() {
            if p < 16 {
                assert_eq!(a.to_bits(), b.to_bits(), "pos {p}");
            } else {
                assert!((a - b).abs() < 1e-3, "pos {p}: {a} vs {b}");
            }
        }
        // refilling re-quantizes the tail tile without panicking
        let k = vec![0.25; 2 * 8];
        for _ in 0..12 {
            cq.push(&k, &k);
        }
        assert_eq!(cq.len, 35);
        assert!(cq.quantized_key_row(0, 17).is_some());
    }

    #[test]
    fn truncate_matches_fresh_fill() {
        // truncating to n must leave the same state (incl. page summaries)
        // as pushing only the first n entries into a fresh cache
        let mut r = Rng::new(9);
        let (n_kv, d, len, n) = (2, 8, 40, 23); // 23 = mid-page for page_size 16
        let mut rows = Vec::new();
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            rows.push((k, v));
        }
        let mut full = KvCache::new(n_kv, d, len);
        let mut short = KvCache::new(n_kv, d, len);
        for (k, v) in &rows {
            full.push(k, v);
        }
        for (k, v) in rows.iter().take(n) {
            short.push(k, v);
        }
        full.truncate(n);
        assert_eq!(full.len, n);
        assert_eq!(full.n_pages(), short.n_pages());
        for h in 0..n_kv {
            for p in 0..n {
                assert_eq!(full.key(h, p), short.key(h, p));
                assert_eq!(full.val(h, p), short.val(h, p));
            }
            for page in 0..full.n_pages() {
                let (amin, amax) = full.page_summary(h, page);
                let (bmin, bmax) = short.page_summary(h, page);
                assert_eq!(amin, bmin, "page {page} min");
                assert_eq!(amax, bmax, "page {page} max");
            }
        }
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
        }
    }

    fn assert_cost_eq(a: &CostTracker, b: &CostTracker, what: &str) {
        assert_eq!(a.score_key_reads, b.score_key_reads, "{what}: score_key_reads");
        assert_eq!(a.attend_kv_reads, b.attend_kv_reads, "{what}: attend_kv_reads");
        assert_eq!(a.topk_items, b.topk_items, "{what}: topk_items");
        assert_eq!(a.dequant_rows, b.dequant_rows, "{what}: dequant_rows");
    }

    /// The acceptance invariant for the tile-major rework: on random
    /// inputs — all four storage modes, including a partial staging tail
    /// and odd (non-tile-multiple) lengths — every rewritten kernel
    /// produces BITWISE the same outputs, pooled scores, Top-k
    /// selections, and cost accounting as the seed row-at-a-time kernels
    /// in [`reference`].
    #[test]
    fn tile_kernels_bitwise_equal_seed_kernels() {
        let mut r = Rng::new(0x71E5);
        let cases = if cfg!(miri) { 4 } else { 8 }; // each dtype at least once
        for case in 0..cases {
            let (n_kv, g, d) = (2usize, 2usize, 16usize);
            let n_q = n_kv * g;
            let len = 30 + r.below(80); // spans partial tiles + staging tails
            let dtype = match case % 4 {
                0 => crate::config::KvDtype::F32,
                1 => crate::config::KvDtype::F16,
                2 => crate::config::KvDtype::Int8,
                _ => crate::config::KvDtype::Int4,
            };
            let mut q = vec![0.0; n_q * d];
            r.fill_normal(&mut q, 1.0);
            let mut cache = KvCache::with_opts(n_kv, d, len + 8, 16, dtype);
            for _ in 0..len {
                let mut k = vec![0.0; n_kv * d];
                let mut v = vec![0.0; n_kv * d];
                r.fill_normal(&mut k, 0.5);
                r.fill_normal(&mut v, 1.0);
                cache.push(&k, &v);
            }
            let mut scratch = AttnScratch::new();
            let tag = dtype.label();

            // dense decode
            let mut out_new = vec![0.0; n_q * d];
            let mut out_ref = vec![0.0; n_q * d];
            let mut c_new = CostTracker::default();
            let mut c_ref = CostTracker::default();
            decode_dense(&q, &cache, g, &mut out_new, &mut scratch.planes, &mut c_new);
            reference::decode_dense(&q, &cache, g, &mut out_ref, &mut c_ref);
            assert_bits_eq(&out_new, &out_ref, &format!("decode_dense/{tag}"));
            assert_cost_eq(&c_new, &c_ref, &format!("decode_dense/{tag}"));

            // pooled scores + top-k selection
            let k_sel = 1 + r.below(len);
            let mut c_new = CostTracker::default();
            let mut c_ref = CostTracker::default();
            decode_pooled_scores(&q, &cache, g, &mut scratch.planes, &mut c_new);
            let pooled_ref = reference::decode_pooled_scores(&q, &cache, g, &mut c_ref);
            for h in 0..n_kv {
                let tagh = format!("pooled/{tag}/h{h}");
                assert_bits_eq(scratch.planes.pooled_head(h), &pooled_ref[h], &tagh);
            }
            select_topk(&mut scratch, k_sel, &mut c_new);
            let sel_ref = reference::select_topk(&pooled_ref, k_sel, &mut c_ref);
            assert_eq!(scratch.sel.to_nested(), sel_ref, "select_topk/{tag}");
            assert_cost_eq(&c_new, &c_ref, &format!("pooled+topk/{tag}"));

            // sparse decode over the selected set (same order)
            let mut out_new = vec![0.0; n_q * d];
            let mut out_ref = vec![0.0; n_q * d];
            let mut c_new = CostTracker::default();
            let mut c_ref = CostTracker::default();
            {
                let AttnScratch { sel, planes } = &mut scratch;
                decode_sparse(&q, &cache, g, sel, &mut out_new, planes, &mut c_new);
            }
            reference::decode_sparse(&q, &cache, g, &sel_ref, &mut out_ref, &mut c_ref);
            assert_bits_eq(&out_new, &out_ref, &format!("decode_sparse/{tag}"));
            assert_cost_eq(&c_new, &c_ref, &format!("decode_sparse/{tag}"));

            // prefill: dense tile, pooled scores, sparse tile
            let tile = 8 + r.below(8);
            let start = len - tile;
            let mut qs = vec![0.0; tile * n_q * d];
            r.fill_normal(&mut qs, 1.0);
            let mut out_new = vec![0.0; tile * n_q * d];
            let mut out_ref = vec![0.0; tile * n_q * d];
            let mut c_new = CostTracker::default();
            let mut c_ref = CostTracker::default();
            let planes = &mut scratch.planes;
            prefill_dense_tile(&qs, start, &cache, g, &mut out_new, planes, &mut c_new);
            reference::prefill_dense_tile(&qs, start, &cache, g, &mut out_ref, &mut c_ref);
            assert_bits_eq(&out_new, &out_ref, &format!("prefill_dense/{tag}"));
            assert_cost_eq(&c_new, &c_ref, &format!("prefill_dense/{tag}"));

            let mut c_new = CostTracker::default();
            let mut c_ref = CostTracker::default();
            prefill_pooled_scores(&qs, start, &cache, g, &mut scratch.planes, &mut c_new);
            let ppool_ref = reference::prefill_pooled_scores(&qs, start, &cache, g, &mut c_ref);
            for h in 0..n_kv {
                let tagh = format!("prefill_pooled/{tag}/h{h}");
                assert_bits_eq(scratch.planes.pooled_head(h), &ppool_ref[h], &tagh);
            }
            assert_cost_eq(&c_new, &c_ref, &format!("prefill_pooled/{tag}"));

            select_topk(&mut scratch, k_sel, &mut CostTracker::default());
            let psel_ref = reference::select_topk(&ppool_ref, k_sel, &mut CostTracker::default());
            let mut out_new = vec![0.0; tile * n_q * d];
            let mut out_ref = vec![0.0; tile * n_q * d];
            let mut c_new = CostTracker::default();
            let mut c_ref = CostTracker::default();
            {
                let AttnScratch { sel, planes } = &mut scratch;
                prefill_sparse_tile(&qs, start, &cache, g, sel, &mut out_new, planes, &mut c_new);
            }
            reference::prefill_sparse_tile(
                &qs, start, &cache, g, &psel_ref, &mut out_ref, &mut c_ref,
            );
            assert_bits_eq(&out_new, &out_ref, &format!("prefill_sparse/{tag}"));
            assert_cost_eq(&c_new, &c_ref, &format!("prefill_sparse/{tag}"));
        }
    }

    /// Head-granular kernels (the parallel tick's work-item granularity)
    /// compose bitwise into the full-row kernels.
    #[test]
    fn head_kernels_compose_bitwise() {
        let (q, cache) = setup(2, 2, 16, 50, 17);
        let (n_kv, g, d) = (2usize, 2usize, 16usize);
        let gd = g * d;
        let mut full = vec![0.0; n_kv * gd];
        let mut per_head = vec![0.0; n_kv * gd];
        let mut planes = ScorePlanes::default();
        let mut c = CostTracker::default();
        decode_dense(&q, &cache, g, &mut full, &mut planes, &mut c);
        let mut c2 = CostTracker::default();
        for h in 0..n_kv {
            let out_h = &mut per_head[h * gd..(h + 1) * gd];
            decode_dense_head(&q, h, cache.len, &cache, g, out_h, &mut planes, &mut c2);
        }
        assert_bits_eq(&full, &per_head, "dense head composition");
        assert_cost_eq(&c, &c2, "dense head composition");

        let sel = IndexSet::from_nested(&[vec![3, 9, 14, 40], vec![0, 7, 21]]);
        let mut full = vec![0.0; n_kv * gd];
        let mut per_head = vec![0.0; n_kv * gd];
        decode_sparse(&q, &cache, g, &sel, &mut full, &mut planes, &mut c);
        for h in 0..n_kv {
            let out_h = &mut per_head[h * gd..(h + 1) * gd];
            decode_sparse_head(&q, h, sel.head(h), &cache, g, out_h, &mut planes, &mut c);
        }
        assert_bits_eq(&full, &per_head, "sparse head composition");
    }
}
