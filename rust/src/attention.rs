//! Native CPU GQA attention engine — the simulator substrate for the
//! paper's accuracy and kernel-shape experiments.
//!
//! Mirrors the semantics of the Pallas kernels (python/compile/kernels/):
//! dense decode/prefill, post-softmax pooled scores (GQA pooling in
//! decode, Q-tile pooling in prefill), sparse attention over explicit
//! per-KV-head index sets with causal clamping, and the multi-pass anchor
//! pipeline cost structure.  A [`CostTracker`] accounts key/value reads and
//! score FLOPs so experiments can report work ratios alongside wall-clock.

use crate::tensor::{dot, softmax, topk_indices_unordered};

/// Per-layer KV cache: contiguous `[n_kv, cap, d]` buffers plus optional
/// per-page min/max summaries (used by the Quest baseline).
#[derive(Clone)]
pub struct KvCache {
    pub n_kv: usize,
    pub d: usize,
    pub cap: usize,
    pub len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// page summaries: for each kv head and page, elementwise min and max
    /// of the keys in the page: `[n_kv, n_pages, 2, d]`.
    page_size: usize,
    pages: Vec<f32>,
}

impl KvCache {
    pub fn new(n_kv: usize, d: usize, cap: usize) -> Self {
        Self::with_page_size(n_kv, d, cap, 16)
    }

    pub fn with_page_size(n_kv: usize, d: usize, cap: usize, page_size: usize) -> Self {
        let n_pages = cap.div_ceil(page_size);
        Self {
            n_kv,
            d,
            cap,
            len: 0,
            k: vec![0.0; n_kv * cap * d],
            v: vec![0.0; n_kv * cap * d],
            page_size,
            pages: vec![0.0; n_kv * n_pages * 2 * d],
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn n_pages(&self) -> usize {
        self.len.div_ceil(self.page_size)
    }

    /// Append one position: `k_new`/`v_new` are `[n_kv * d]` (head-major).
    pub fn push(&mut self, k_new: &[f32], v_new: &[f32]) {
        assert!(self.len < self.cap, "KV cache overflow (cap {})", self.cap);
        debug_assert_eq!(k_new.len(), self.n_kv * self.d);
        let pos = self.len;
        let page = pos / self.page_size;
        let fresh_page = pos % self.page_size == 0;
        for h in 0..self.n_kv {
            let dst = (h * self.cap + pos) * self.d;
            self.k[dst..dst + self.d].copy_from_slice(&k_new[h * self.d..(h + 1) * self.d]);
            self.v[dst..dst + self.d].copy_from_slice(&v_new[h * self.d..(h + 1) * self.d]);
            // update page min/max
            let pb = ((h * self.cap.div_ceil(self.page_size)) + page) * 2 * self.d;
            let (mins, rest) = self.pages[pb..pb + 2 * self.d].split_at_mut(self.d);
            let maxs = rest;
            let krow = &k_new[h * self.d..(h + 1) * self.d];
            if fresh_page {
                mins.copy_from_slice(krow);
                maxs.copy_from_slice(krow);
            } else {
                for i in 0..self.d {
                    mins[i] = mins[i].min(krow[i]);
                    maxs[i] = maxs[i].max(krow[i]);
                }
            }
        }
        self.len += 1;
    }

    #[inline]
    pub fn key(&self, h: usize, pos: usize) -> &[f32] {
        let o = (h * self.cap + pos) * self.d;
        &self.k[o..o + self.d]
    }

    #[inline]
    pub fn val(&self, h: usize, pos: usize) -> &[f32] {
        let o = (h * self.cap + pos) * self.d;
        &self.v[o..o + self.d]
    }

    /// (min, max) key summary of `page` for head `h`.
    pub fn page_summary(&self, h: usize, page: usize) -> (&[f32], &[f32]) {
        let pb = ((h * self.cap.div_ceil(self.page_size)) + page) * 2 * self.d;
        (&self.pages[pb..pb + self.d], &self.pages[pb + self.d..pb + 2 * self.d])
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Truncate to the first `n` positions (prefix-cache snapshot forks).
    /// The (now partial) last page's min/max summary is rebuilt from the
    /// raw keys so Quest-style page bounds stay exact after truncation.
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.len, "truncate {n} beyond len {}", self.len);
        self.len = n;
        if n == 0 {
            return;
        }
        let page = (n - 1) / self.page_size;
        let p0 = page * self.page_size;
        let d = self.d;
        for h in 0..self.n_kv {
            let mut mins = vec![f32::INFINITY; d];
            let mut maxs = vec![f32::NEG_INFINITY; d];
            for pos in p0..n {
                let o = (h * self.cap + pos) * d;
                for i in 0..d {
                    let x = self.k[o + i];
                    mins[i] = mins[i].min(x);
                    maxs[i] = maxs[i].max(x);
                }
            }
            let pb = ((h * self.cap.div_ceil(self.page_size)) + page) * 2 * d;
            self.pages[pb..pb + d].copy_from_slice(&mins);
            self.pages[pb + d..pb + 2 * d].copy_from_slice(&maxs);
        }
    }
}

/// Work accounting for the cost-model side of Table 3 / Fig 8.
#[derive(Debug, Default, Clone, Copy)]
pub struct CostTracker {
    /// K rows read for score computation (dense or estimation passes).
    pub score_key_reads: u64,
    /// K/V rows read for the weighted-sum (output) computation.
    pub attend_kv_reads: u64,
    /// Entries pushed through top-k selection.
    pub topk_items: u64,
}

impl CostTracker {
    pub fn merge(&mut self, o: &CostTracker) {
        self.score_key_reads += o.score_key_reads;
        self.attend_kv_reads += o.attend_kv_reads;
        self.topk_items += o.topk_items;
    }
}

/// Scale for all scores: 1/sqrt(d).
#[inline]
fn scale(d: usize) -> f32 {
    1.0 / (d as f32).sqrt()
}

// ---------------------------------------------------------------------------
// decode attention
// ---------------------------------------------------------------------------

/// Dense GQA decode attention.  `q` is `[n_q * d]` head-major, `out` too.
/// Attends to `cache.len` keys.
pub fn decode_dense(q: &[f32], cache: &KvCache, g: usize, out: &mut [f32], cost: &mut CostTracker) {
    let (d, len, n_kv) = (cache.d, cache.len, cache.n_kv);
    let sc = scale(d);
    let mut s = vec![0.0f32; len];
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for p in 0..len {
                s[p] = dot(qrow, cache.key(h, p)) * sc;
            }
            softmax(&mut s);
            let orow = &mut out[hq * d..(hq + 1) * d];
            orow.fill(0.0);
            for p in 0..len {
                let w = s[p];
                if w > 1e-9 {
                    crate::tensor::axpy(orow, w, cache.val(h, p));
                }
            }
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    cost.attend_kv_reads += (n_kv * g * len) as u64;
}

/// Per-query-head post-softmax distributions for one decode query:
/// `[n_q][len]`.
pub fn decode_head_scores(q: &[f32], cache: &KvCache, g: usize, cost: &mut CostTracker) -> Vec<Vec<f32>> {
    let (d, len, n_kv) = (cache.d, cache.len, cache.n_kv);
    let sc = scale(d);
    let mut all = Vec::with_capacity(n_kv * g);
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            let mut s = vec![0.0f32; len];
            for p in 0..len {
                s[p] = dot(qrow, cache.key(h, p)) * sc;
            }
            softmax(&mut s);
            all.push(s);
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    all
}

/// GQA post-softmax pooling (paper Sec. 3.4, decode): mean of the group's
/// distributions, per KV head: `[n_kv][len]`.
pub fn decode_pooled_scores(q: &[f32], cache: &KvCache, g: usize, cost: &mut CostTracker) -> Vec<Vec<f32>> {
    let per_head = decode_head_scores(q, cache, g, cost);
    pool_groups(&per_head, g)
}

/// Pooled scores clamped to the first `upto` cache entries (used for
/// calibration probes at prefill positions).
pub fn decode_pooled_scores_upto(
    q: &[f32],
    upto: usize,
    cache: &KvCache,
    g: usize,
    cost: &mut CostTracker,
) -> Vec<Vec<f32>> {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let len = upto.min(cache.len);
    let sc = scale(d);
    let inv = 1.0 / g as f32;
    let mut pooled = vec![vec![0.0f32; len]; n_kv];
    let mut s = vec![0.0f32; len];
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for p in 0..len {
                s[p] = dot(qrow, cache.key(h, p)) * sc;
            }
            softmax(&mut s);
            for p in 0..len {
                pooled[h][p] += s[p] * inv;
            }
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    pooled
}

/// Mean-pool groups of `g` consecutive distributions.
pub fn pool_groups(per_head: &[Vec<f32>], g: usize) -> Vec<Vec<f32>> {
    let n_kv = per_head.len() / g;
    let len = per_head[0].len();
    let inv = 1.0 / g as f32;
    (0..n_kv)
        .map(|h| {
            let mut p = vec![0.0f32; len];
            for qi in 0..g {
                for (pi, &x) in p.iter_mut().zip(per_head[h * g + qi].iter()) {
                    *pi += x * inv;
                }
            }
            p
        })
        .collect()
}

/// Sparse decode attention over per-KV-head index sets.
pub fn decode_sparse(
    q: &[f32],
    cache: &KvCache,
    g: usize,
    idx: &[Vec<u32>],
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let sc = scale(d);
    let mut total = 0u64;
    for (h, hidx) in idx.iter().enumerate() {
        let mut s = vec![0.0f32; hidx.len()];
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for (j, &p) in hidx.iter().enumerate() {
                s[j] = dot(qrow, cache.key(h, p as usize)) * sc;
            }
            softmax(&mut s);
            let orow = &mut out[hq * d..(hq + 1) * d];
            orow.fill(0.0);
            for (j, &p) in hidx.iter().enumerate() {
                if s[j] > 1e-9 {
                    crate::tensor::axpy(orow, s[j], cache.val(h, p as usize));
                }
            }
        }
        total += (g * hidx.len()) as u64;
    }
    cost.score_key_reads += total;
    cost.attend_kv_reads += total;
}

// ---------------------------------------------------------------------------
// prefill attention (tile-based)
// ---------------------------------------------------------------------------

/// Dense causal prefill attention for a tile of queries.
///
/// `qs` is `[tile, n_q * d]`; query row `r` sits at absolute position
/// `start + r` and attends to keys `[0, start + r]` (the cache must already
/// contain the tile's own keys).  `out` is `[tile, n_q * d]`.
pub fn prefill_dense_tile(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let n_q = cache.n_kv * g;
    let tile = qs.len() / (n_q * d);
    for r in 0..tile {
        decode_dense_upto(
            &qs[r * n_q * d..(r + 1) * n_q * d],
            start + r + 1,
            cache,
            g,
            &mut out[r * n_q * d..(r + 1) * n_q * d],
            cost,
        );
    }
}

/// Dense decode attention clamped to the first `upto` cache entries.
pub fn decode_dense_upto(
    q: &[f32],
    upto: usize,
    cache: &KvCache,
    g: usize,
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let len = upto.min(cache.len);
    let sc = scale(d);
    let mut s = vec![0.0f32; len];
    for h in 0..n_kv {
        for qi in 0..g {
            let hq = h * g + qi;
            let qrow = &q[hq * d..(hq + 1) * d];
            for p in 0..len {
                s[p] = dot(qrow, cache.key(h, p)) * sc;
            }
            softmax(&mut s);
            let orow = &mut out[hq * d..(hq + 1) * d];
            orow.fill(0.0);
            for p in 0..len {
                if s[p] > 1e-9 {
                    crate::tensor::axpy(orow, s[p], cache.val(h, p));
                }
            }
        }
    }
    cost.score_key_reads += (n_kv * g * len) as u64;
    cost.attend_kv_reads += (n_kv * g * len) as u64;
}

/// Tile-level post-softmax pooled scores for prefill (anchor passes 1+2):
/// the mean over (GQA group x tile rows) of each query's causal
/// post-softmax distribution, per KV head: `[n_kv][kv_len]` where
/// `kv_len = start + tile`.
pub fn prefill_pooled_scores(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    cost: &mut CostTracker,
) -> Vec<Vec<f32>> {
    let (d, n_kv) = (cache.d, cache.n_kv);
    let n_q = n_kv * g;
    let tile = qs.len() / (n_q * d);
    let kv_len = (start + tile).min(cache.len);
    let sc = scale(d);
    let inv = 1.0 / (tile * g) as f32;
    // causal triangular work: row r reads min(start + r + 1, kv_len) keys
    // per (head, group) query — NOT tile * kv_len (Fig. 8 / Table 3 cost
    // ratios were overcounting the anchor pass before this was fixed)
    let row_reads: u64 = (0..tile).map(|r| (start + r + 1).min(kv_len) as u64).sum();
    let mut pooled = vec![vec![0.0f32; kv_len]; n_kv];
    let mut s = vec![0.0f32; kv_len];
    for h in 0..n_kv {
        for r in 0..tile {
            let upto = (start + r + 1).min(kv_len);
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                for p in 0..upto {
                    s[p] = dot(qrow, cache.key(h, p)) * sc;
                }
                softmax(&mut s[..upto]);
                for p in 0..upto {
                    pooled[h][p] += s[p] * inv;
                }
            }
        }
        cost.score_key_reads += g as u64 * row_reads;
    }
    pooled
}

/// Sparse prefill attention for a tile with tile-shared indices and
/// per-query causal clamping (paper Sec. 3.4 / 4.1 rolling Top-k).
pub fn prefill_sparse_tile(
    qs: &[f32],
    start: usize,
    cache: &KvCache,
    g: usize,
    idx: &[Vec<u32>],
    out: &mut [f32],
    cost: &mut CostTracker,
) {
    let d = cache.d;
    let n_q = cache.n_kv * g;
    let tile = qs.len() / (n_q * d);
    let sc = scale(d);
    for r in 0..tile {
        let qpos = start + r;
        for (h, hidx) in idx.iter().enumerate() {
            let mut s = Vec::with_capacity(hidx.len() + r + 1);
            let mut kept: Vec<u32> = Vec::with_capacity(hidx.len() + r + 1);
            // which of the tile's own (causally visible) positions the
            // index set already covers: offset j <=> position start + j
            let mut own = vec![false; r + 1];
            for &p in hidx {
                if (p as usize) <= qpos {
                    kept.push(p);
                    if (p as usize) >= start {
                        own[p as usize - start] = true;
                    }
                }
            }
            // rolling-Top-k guarantee (paper Sec. 4.1): a tile's own
            // positions are always visible to its queries, even when the
            // anchor's indices all land in this query's causal future
            for (j, seen) in own.iter().enumerate() {
                if !seen {
                    kept.push((start + j) as u32);
                }
            }
            for qi in 0..g {
                let hq = h * g + qi;
                let qrow = &qs[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                s.clear();
                for &p in &kept {
                    s.push(dot(qrow, cache.key(h, p as usize)) * sc);
                }
                softmax(&mut s);
                let orow = &mut out[(r * n_q + hq) * d..(r * n_q + hq + 1) * d];
                orow.fill(0.0);
                for (j, &p) in kept.iter().enumerate() {
                    if s[j] > 1e-9 {
                        crate::tensor::axpy(orow, s[j], cache.val(h, p as usize));
                    }
                }
            }
            cost.score_key_reads += (g * kept.len()) as u64;
            cost.attend_kv_reads += (g * kept.len()) as u64;
        }
    }
}

/// Top-k over pooled scores (anchor pass 3).  Uses the O(n) unordered
/// quickselect — attention is order-invariant over the index set.
pub fn select_topk(pooled: &[Vec<f32>], k: usize, cost: &mut CostTracker) -> Vec<Vec<u32>> {
    pooled
        .iter()
        .map(|p| {
            cost.topk_items += p.len() as u64;
            topk_indices_unordered(p, k.min(p.len()))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(n_kv: usize, g: usize, d: usize, len: usize, seed: u64) -> (Vec<f32>, KvCache) {
        let mut r = Rng::new(seed);
        let n_q = n_kv * g;
        let mut q = vec![0.0; n_q * d];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(n_kv, d, len + 8);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        (q, cache)
    }

    #[test]
    fn dense_decode_is_convex_combination() {
        let (q, cache) = setup(2, 2, 16, 64, 1);
        let mut out = vec![0.0; 4 * 16];
        let mut c = CostTracker::default();
        decode_dense(&q, &cache, 2, &mut out, &mut c);
        // bounded by value hull per kv head
        for h in 0..2 {
            let mut vmax = f32::NEG_INFINITY;
            let mut vmin = f32::INFINITY;
            for p in 0..64 {
                for &x in cache.val(h, p) {
                    vmax = vmax.max(x);
                    vmin = vmin.min(x);
                }
            }
            for qi in 0..2 {
                for &x in &out[(h * 2 + qi) * 16..(h * 2 + qi + 1) * 16] {
                    assert!(x <= vmax + 1e-4 && x >= vmin - 1e-4);
                }
            }
        }
        assert_eq!(c.score_key_reads, 4 * 64);
    }

    #[test]
    fn sparse_with_all_indices_equals_dense() {
        let (q, cache) = setup(2, 2, 16, 64, 2);
        let mut dense = vec![0.0; 4 * 16];
        let mut sparse = vec![0.0; 4 * 16];
        let mut c = CostTracker::default();
        decode_dense(&q, &cache, 2, &mut dense, &mut c);
        let idx: Vec<Vec<u32>> = vec![(0..64).collect(), (0..64).collect()];
        decode_sparse(&q, &cache, 2, &idx, &mut sparse, &mut c);
        for (a, b) in dense.iter().zip(&sparse) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn pooled_scores_are_distributions() {
        let (q, cache) = setup(2, 2, 16, 64, 3);
        let mut c = CostTracker::default();
        let pooled = decode_pooled_scores(&q, &cache, 2, &mut c);
        assert_eq!(pooled.len(), 2);
        for p in &pooled {
            assert_eq!(p.len(), 64);
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn topk_sparse_approximates_dense_when_peaked() {
        // make one key align strongly with the query
        let mut r = Rng::new(4);
        let (n_kv, g, d, len) = (2, 2, 16, 128);
        let mut q = vec![0.0; n_kv * g * d];
        r.fill_normal(&mut q, 1.0);
        let mut cache = KvCache::new(n_kv, d, len);
        for p in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.2);
            r.fill_normal(&mut v, 1.0);
            if p == 77 {
                // strong alignment for every (kv, q) pair
                for h in 0..n_kv {
                    for i in 0..d {
                        k[h * d + i] = q[h * g * d + i] * 2.0;
                    }
                }
            }
            cache.push(&k, &v);
        }
        let mut c = CostTracker::default();
        let pooled = decode_pooled_scores(&q, &cache, g, &mut c);
        let idx = select_topk(&pooled, 16, &mut c);
        assert!(idx.iter().all(|hi| hi.contains(&77)));
        let mut dense = vec![0.0; n_kv * g * d];
        let mut sparse = vec![0.0; n_kv * g * d];
        decode_dense(&q, &cache, g, &mut dense, &mut c);
        decode_sparse(&q, &cache, g, &idx, &mut sparse, &mut c);
        let cos = crate::tensor::cosine_sim(&dense, &sparse);
        assert!(cos > 0.9, "cos {cos}");
    }

    #[test]
    fn prefill_dense_tile_matches_per_token_decode() {
        let mut r = Rng::new(5);
        let (n_kv, g, d, len) = (2, 2, 8, 32);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, len);
        let mut qs = vec![0.0; len * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut c = CostTracker::default();
        let mut tile_out = vec![0.0; len * n_q * d];
        prefill_dense_tile(&qs, 0, &cache, g, &mut tile_out, &mut c);
        for t in 0..len {
            let mut want = vec![0.0; n_q * d];
            decode_dense_upto(&qs[t * n_q * d..(t + 1) * n_q * d], t + 1, &cache, g, &mut want, &mut c);
            for (a, b) in tile_out[t * n_q * d..(t + 1) * n_q * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prefill_pooled_rows_sum_to_one() {
        let mut r = Rng::new(6);
        let (n_kv, g, d, tile) = (2, 2, 8, 16);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 64);
        for _ in 0..48 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut c = CostTracker::default();
        let pooled = prefill_pooled_scores(&qs, 32, &cache, g, &mut c);
        for p in &pooled {
            assert_eq!(p.len(), 48);
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "sum {sum}");
        }
    }

    #[test]
    fn prefill_sparse_clamps_future_indices() {
        let mut r = Rng::new(7);
        let (n_kv, g, d, tile) = (1, 2, 8, 8);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 16);
        for _ in 0..8 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        // indices include every position; query 0 may only use position 0
        let idx = vec![(0..8u32).collect::<Vec<_>>()];
        let mut out = vec![0.0; tile * n_q * d];
        let mut c = CostTracker::default();
        prefill_sparse_tile(&qs, 0, &cache, g, &idx, &mut out, &mut c);
        for hq in 0..n_q {
            for i in 0..d {
                assert!((out[hq * d + i] - cache.val(0, 0)[i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prefill_sparse_always_sees_tile_own_positions() {
        // all anchor indices land in the tile's future: every query must
        // still see the tile's own causally-visible range (Sec. 4.1), not
        // collapse to self-only attention
        let mut r = Rng::new(12);
        let (n_kv, g, d, tile, start) = (1usize, 2usize, 8usize, 8usize, 8usize);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 16);
        for _ in 0..16 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        // anchor indices all at the end of the tile (future for early rows)
        let idx = vec![vec![12u32, 13, 14, 15]];
        let mut out = vec![0.0; tile * n_q * d];
        let mut c = CostTracker::default();
        prefill_sparse_tile(&qs, start, &cache, g, &idx, &mut out, &mut c);
        for row in 0..tile {
            let qpos = start + row;
            // expected: attention over the union {idx <= qpos} u {start..=qpos},
            // which here is exactly the tile's own visible range
            let expect_idx: Vec<Vec<u32>> = vec![(start as u32..=qpos as u32).collect()];
            let mut want = vec![0.0; n_q * d];
            decode_sparse(
                &qs[row * n_q * d..(row + 1) * n_q * d],
                &cache,
                g,
                &expect_idx,
                &mut want,
                &mut CostTracker::default(),
            );
            for (a, b) in out[row * n_q * d..(row + 1) * n_q * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "row {row}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn prefill_pooled_cost_matches_dense_tile_pass() {
        // the anchor estimation pass reads exactly the causal triangle of
        // keys — its accounted cost must equal the dense tile pass's
        let mut r = Rng::new(13);
        let (n_kv, g, d, tile, start) = (2usize, 2usize, 8usize, 16usize, 32usize);
        let n_q = n_kv * g;
        let mut cache = KvCache::new(n_kv, d, 64);
        for _ in 0..48 {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            cache.push(&k, &v);
        }
        let mut qs = vec![0.0; tile * n_q * d];
        r.fill_normal(&mut qs, 1.0);
        let mut c_pool = CostTracker::default();
        let _ = prefill_pooled_scores(&qs, start, &cache, g, &mut c_pool);
        let mut c_dense = CostTracker::default();
        let mut out = vec![0.0; tile * n_q * d];
        prefill_dense_tile(&qs, start, &cache, g, &mut out, &mut c_dense);
        assert_eq!(c_pool.score_key_reads, c_dense.score_key_reads);
        // triangular sum, explicitly: sum_r min(start + r + 1, kv_len)
        let want: u64 = (0..tile).map(|r| (start + r + 1).min(48) as u64).sum();
        assert_eq!(c_pool.score_key_reads, (n_kv * g) as u64 * want);
    }

    #[test]
    fn page_summaries_bound_keys() {
        let (_, cache) = setup(2, 2, 16, 70, 8);
        for h in 0..2 {
            for page in 0..cache.n_pages() {
                let (mins, maxs) = cache.page_summary(h, page);
                let lo = page * cache.page_size();
                let hi = ((page + 1) * cache.page_size()).min(cache.len);
                for p in lo..hi {
                    for (i, &x) in cache.key(h, p).iter().enumerate() {
                        assert!(x >= mins[i] - 1e-6 && x <= maxs[i] + 1e-6);
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn cache_overflow_panics() {
        let mut cache = KvCache::new(1, 4, 2);
        let k = vec![0.0; 4];
        for _ in 0..3 {
            cache.push(&k, &k);
        }
    }

    #[test]
    fn truncate_matches_fresh_fill() {
        // truncating to n must leave the same state (incl. page summaries)
        // as pushing only the first n entries into a fresh cache
        let mut r = Rng::new(9);
        let (n_kv, d, len, n) = (2, 8, 40, 23); // 23 = mid-page for page_size 16
        let mut rows = Vec::new();
        for _ in 0..len {
            let mut k = vec![0.0; n_kv * d];
            let mut v = vec![0.0; n_kv * d];
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 1.0);
            rows.push((k, v));
        }
        let mut full = KvCache::new(n_kv, d, len);
        let mut short = KvCache::new(n_kv, d, len);
        for (k, v) in &rows {
            full.push(k, v);
        }
        for (k, v) in rows.iter().take(n) {
            short.push(k, v);
        }
        full.truncate(n);
        assert_eq!(full.len, n);
        assert_eq!(full.n_pages(), short.n_pages());
        for h in 0..n_kv {
            for p in 0..n {
                assert_eq!(full.key(h, p), short.key(h, p));
                assert_eq!(full.val(h, p), short.val(h, p));
            }
            for page in 0..full.n_pages() {
                let (amin, amax) = full.page_summary(h, page);
                let (bmin, bmax) = short.page_summary(h, page);
                assert_eq!(amin, bmin, "page {page} min");
                assert_eq!(amax, bmax, "page {page} max");
            }
        }
    }
}
