//! Coordinator hot-path benches: scheduler tick formation, block manager
//! churn, router throughput, the step-batched decode engine, and the
//! prefix-cache RAG scenario — the L3 overheads and wins that frame the
//! paper's serving numbers.
//!
//! Run: `cargo bench --bench coordinator`
//! Writes machine-readable results to `results/coordinator_bench.json`.

use kascade::benchutil::{bench, header};
use kascade::config::ServeConfig;
use kascade::coordinator::{BlockManager, NativeBackend, Request, Router, SeqBackend, Sequence};
use kascade::jsonutil::Json;
use kascade::model::SynthSpec;
use kascade::server::{Completion, Engine};
use kascade::sparse::DensePolicy;
use kascade::workload::WorkloadGen;
use std::cell::Cell;
use std::rc::Rc;
use std::sync::Arc;

struct NullBackend;

impl SeqBackend for NullBackend {
    fn prefill_chunk(&mut self, _tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        vec![0.0, 1.0]
    }
}

/// Null-compute backend that counts prefilled tokens and supports
/// prefix-cache snapshots (state is just the token count).
struct CountingBackend {
    prefilled: Rc<Cell<u64>>,
    tokens: usize,
}

impl SeqBackend for CountingBackend {
    fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        self.tokens += tokens.len();
        self.prefilled.set(self.prefilled.get() + tokens.len() as u64);
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        self.tokens += 1;
        vec![0.0, 1.0]
    }

    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        if tokens > self.tokens {
            return None;
        }
        Some(Box::new(CountingBackend { prefilled: self.prefilled.clone(), tokens }))
    }
}

fn main() {
    header();

    // block manager: alloc/extend/free churn
    let mut bm = BlockManager::new(16, 65536);
    let mut next = 0u64;
    bench("block_manager extend+release x1000", 3, 30, || {
        for _ in 0..1000 {
            next += 1;
            bm.extend(next % 512, ((next * 37) % 2000) as usize + 1);
            if next % 3 == 0 {
                bm.release((next + 100) % 512);
            }
        }
    });

    // router
    let mut router = Router::new(8);
    bench("router route x10k (mixed affinity)", 3, 30, || {
        for i in 0..10_000u64 {
            let w = router.route(if i % 2 == 0 { Some(i % 64) } else { None });
            router.release(w);
        }
    });

    // scheduler tick with a large running set (null compute)
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 1 << 16,
        max_running: 256,
        token_budget: 4096,
        prefill_chunk: 512,
        queue_cap: 4096,
        workers: 1,
        ..ServeConfig::default()
    };
    let mut engine = Engine::new(cfg, Box::new(|_req: &Request| Box::new(NullBackend) as Box<dyn SeqBackend>));
    for id in 0..256u64 {
        engine.submit(Request {
            id,
            prompt: vec![0; 512],
            max_new: 1_000_000, // keep decoding forever
            stop_token: None,
        });
    }
    // warm into decode phase
    for _ in 0..8 {
        engine.tick();
    }
    bench("scheduler tick (256 running decodes)", 3, 100, || {
        engine.tick();
    });
    println!(
        "\nper-sequence scheduling overhead: see mean/256 — target: <1us/seq (paper's L3 must not bottleneck)"
    );

    // prefix caching: 8 RAG requests sharing a 4k-token document prefix.
    // The first request prefills and registers the prefix; the rest
    // adopt its blocks and skip both KV storage and prefill compute.
    let spec = SynthSpec::eval_base(0xCAFE);
    let mut gen = WorkloadGen::new(&spec, 0x5A5);
    let tasks = gen.rag_suite(8, 4096, 64);
    let total_prompt: u64 = tasks.iter().map(|t| t.prompt.len() as u64).sum();
    let cache_cfg = ServeConfig {
        block_size: 16,
        num_blocks: 8192,
        max_running: 8,
        token_budget: 4096,
        prefill_chunk: 512,
        queue_cap: 64,
        workers: 1,
        enable_prefix_cache: true,
        prefix_cache_blocks: 4096,
        batched_decode: true,
    };
    let prefilled = Rc::new(Cell::new(0u64));
    let counter = prefilled.clone();
    let mut engine = Engine::new(
        cache_cfg,
        Box::new(move |_req: &Request| {
            Box::new(CountingBackend { prefilled: counter.clone(), tokens: 0 })
                as Box<dyn SeqBackend>
        }),
    );
    let t0 = std::time::Instant::now();
    for (id, t) in tasks.iter().enumerate() {
        engine.submit(Request {
            id: id as u64,
            prompt: t.prompt.clone(),
            max_new: 2,
            stop_token: None,
        });
        // run each request to completion so request 0's registered
        // prefix is available to every follower (steady-state RAG shape)
        engine.run_to_completion();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = &engine.metrics;
    let saved_frac = m.saved_prefill_tokens as f64 / total_prompt as f64;
    println!(
        "\nprefix caching (8 requests x {} tok, 4096-tok shared prefix):",
        tasks[0].prompt.len()
    );
    println!("  {}", m.report());
    println!(
        "  prefilled {} of {total_prompt} prompt tokens — {:.0}% prefill saved, hit rate {:.0}%, wall {wall:.3}s",
        prefilled.get(),
        saved_frac * 100.0,
        m.prefix_hit_rate() * 100.0
    );
    assert!(
        saved_frac >= 0.5,
        "prefix caching must save >= 50% of prefill tokens (got {:.0}%)",
        saved_frac * 100.0
    );
    engine.sched.blocks.check_invariants().unwrap();

    // step-batched decode: 8 concurrent decoders on the real SynthLM
    // engine, batched vs. sequential.  The tick's decodes run as ONE
    // layer-major pass per model, so every weight matrix is streamed once
    // per token-step instead of once per sequence — the dominant
    // memory-bandwidth cost at small contexts.  Outputs must be
    // IDENTICAL (bitwise-equal logits => identical greedy streams).
    let mut spec = SynthSpec::eval_base(0xD0DE);
    spec.cfg.n_layers = 8;
    spec.block_starts = vec![1, 4];
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0xD1CE);
    let prompts: Vec<Vec<u32>> = (0..8).map(|_| gen.dev_prompt(16)).collect();
    let decode_run = |batched: bool| -> (Vec<Completion>, f64) {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 1024,
            max_running: 8,
            token_budget: 1024,
            prefill_chunk: 128,
            queue_cap: 64,
            workers: 1,
            enable_prefix_cache: false,
            prefix_cache_blocks: 0,
            batched_decode: batched,
        };
        let model = model.clone();
        let mut engine = Engine::new(
            cfg,
            Box::new(move |_req: &Request| {
                Box::new(NativeBackend::new(model.clone(), 64, Box::new(DensePolicy)))
                    as Box<dyn SeqBackend>
            }),
        );
        for (id, p) in prompts.iter().enumerate() {
            engine.submit(Request {
                id: id as u64,
                prompt: p.clone(),
                max_new: 24,
                stop_token: None,
            });
        }
        let mut done = engine.run_to_completion();
        done.sort_by_key(|c| c.id);
        (done, engine.metrics.decode_tok_s())
    };
    let (seq_done, seq_tok_s) = decode_run(false);
    let (bat_done, bat_tok_s) = decode_run(true);
    for (a, b) in seq_done.iter().zip(&bat_done) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.tokens, b.tokens,
            "batched decode must be bitwise-equivalent to sequential (req {})",
            a.id
        );
    }
    let ratio = bat_tok_s / seq_tok_s.max(1e-9);
    println!("\nstep-batched decode (8 decoders x 24 tok, 8-layer SynthLM):");
    println!(
        "  sequential {seq_tok_s:.1} tok/s  batched {bat_tok_s:.1} tok/s  ratio {ratio:.2}x  outputs identical"
    );
    assert!(
        ratio >= 1.5,
        "step-batched decode must reach >= 1.5x sequential tokens/s at batch 8 (got {ratio:.2}x)"
    );

    // machine-readable record (ratio + prefix-cache savings)
    std::fs::create_dir_all("results").expect("results dir");
    let record = Json::obj(vec![
        (
            "step_batched_decode",
            Json::obj(vec![
                ("batch", Json::num(8.0)),
                ("max_new", Json::num(24.0)),
                ("n_layers", Json::num(8.0)),
                ("decode_tok_s_sequential", Json::num(seq_tok_s)),
                ("decode_tok_s_batched", Json::num(bat_tok_s)),
                ("ratio", Json::num(ratio)),
                ("outputs_identical", Json::Bool(true)),
            ]),
        ),
        (
            "prefix_cache",
            Json::obj(vec![
                ("saved_frac", Json::num(saved_frac)),
                ("hit_rate", Json::num(m.prefix_hit_rate())),
            ]),
        ),
    ]);
    std::fs::write("results/coordinator_bench.json", record.to_string())
        .expect("write bench json");
    println!("  wrote results/coordinator_bench.json");

    let _ = Sequence::new(
        Request { id: 0, prompt: vec![], max_new: 0, stop_token: None },
        Box::new(NullBackend),
    );
}
