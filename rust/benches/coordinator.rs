//! Coordinator hot-path benches: scheduler tick formation, block manager
//! churn, router throughput, the step-batched decode engine, the
//! prefix-cache RAG scenario, the streaming-session scenario
//! (handle-observed TTFT fidelity + cancellation block-reclaim latency),
//! the SLO-gated `slo_traffic` scenario (seeded bursty multi-tenant
//! traffic with a 512k-token chunked prefill interleaving live decodes),
//! and the `long_context_tiered` scenario (512Ki-token Kascade decode
//! with the reuse layers' KV under a 25% hot-tile budget spilling to a
//! file-backed tile store — docs/kv-tiers.md) — the L3 overheads and
//! wins that frame the paper's serving numbers.
//!
//! Two serving-boundary scenarios ride on top: `slo_traffic_server`
//! (the same seeded traffic through a multi-worker [`Server`]'s channel
//! boundary) and `gateway` (streamed generations over the loopback HTTP
//! front end with prefix-affinity routing — docs/gateway.md).
//!
//! Run: `cargo bench --bench coordinator` (all scenarios), or a single
//! scenario with `cargo bench --bench coordinator -- --scenario <name>`
//! where `<name>` is one of `micro`, `prefix_cache`,
//! `step_batched_decode`, `quantized_kv`, `simd_kernels`, `streaming`,
//! `parallel_tick`, `slo_traffic`, `long_context_tiered`,
//! `slo_traffic_server`, `gateway`.
//!
//! Writes machine-readable results for the scenarios that ran to
//! `results/coordinator_bench.json` (the CI regression gate needs the
//! full run — a single-scenario pass writes a partial record) and the
//! repo-root perf-trajectory artifact `BENCH_10.json`.

use kascade::attention::KvCache;
use kascade::benchutil::{bench, header};
use kascade::config::{KvDtype, ModelConfig, ServeConfig, TopKRule};
use kascade::coordinator::{
    BlockManager, Completion, Event, NativeBackend, Request, Router, SeqBackend, SeqPhase,
    Sequence, ServeMetrics, Session,
};
use kascade::gateway::{Gateway, GatewayConfig, GatewayServer, NdjsonStream};
use kascade::jsonutil::Json;
use kascade::kascade::KascadePlan;
use kascade::model::{Model, SeqState, SynthSpec, Weights};
use kascade::server::{BackendFactory, Engine, Server};
use kascade::sparse::{DensePolicy, KascadePolicy, SparsePolicy};
use kascade::tensor::{argmax, Rng};
use kascade::tilestore::{shared_store, FileTileStore, TierParams, TierStats};
use kascade::workload::{TrafficGen, TrafficSpec, WorkloadGen};
use std::cell::Cell;
use std::rc::Rc;
use std::sync::Arc;

const SCENARIOS: [&str; 11] = [
    "micro",
    "prefix_cache",
    "step_batched_decode",
    "quantized_kv",
    "simd_kernels",
    "streaming",
    "parallel_tick",
    "slo_traffic",
    "long_context_tiered",
    "slo_traffic_server",
    "gateway",
];

struct NullBackend;

impl SeqBackend for NullBackend {
    fn prefill_chunk(&mut self, _tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        vec![0.0, 1.0]
    }
}

/// Null-compute backend that counts prefilled tokens and supports
/// prefix-cache snapshots (state is just the token count).
struct CountingBackend {
    prefilled: Rc<Cell<u64>>,
    tokens: usize,
}

impl SeqBackend for CountingBackend {
    fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        self.tokens += tokens.len();
        self.prefilled.set(self.prefilled.get() + tokens.len() as u64);
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        self.tokens += 1;
        vec![0.0, 1.0]
    }

    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        if tokens > self.tokens {
            return None;
        }
        Some(Box::new(CountingBackend { prefilled: self.prefilled.clone(), tokens }))
    }
}

fn main() {
    // `cargo bench --bench coordinator -- --scenario <name>` — cargo
    // forwards everything after `--` to the binary; other flags cargo's
    // harness plumbing injects are ignored.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut scenario = String::from("all");
    let mut i = 0;
    while i < argv.len() {
        if argv[i] == "--scenario" {
            match argv.get(i + 1) {
                Some(v) => scenario = v.clone(),
                None => {
                    eprintln!("--scenario needs a value (one of: all {})", SCENARIOS.join(" "));
                    std::process::exit(2);
                }
            }
            i += 1;
        }
        i += 1;
    }
    if scenario != "all" && !SCENARIOS.contains(&scenario.as_str()) {
        eprintln!("unknown scenario '{scenario}' (one of: all {})", SCENARIOS.join(" "));
        std::process::exit(2);
    }
    let run = |name: &str| scenario == "all" || scenario == name;
    let mut record: Vec<(&str, Json)> = Vec::new();

    header();

    if run("micro") {
        // block manager: alloc/extend/free churn
        let mut bm = BlockManager::new(16, 65536);
        let mut next = 0u64;
        bench("block_manager extend+release x1000", 3, 30, || {
            for _ in 0..1000 {
                next += 1;
                bm.extend(next % 512, ((next * 37) % 2000) as usize + 1);
                if next % 3 == 0 {
                    bm.release((next + 100) % 512);
                }
            }
        });

        // router
        let mut router = Router::new(8);
        bench("router route x10k (mixed affinity)", 3, 30, || {
            for i in 0..10_000u64 {
                let w = router.route(if i % 2 == 0 { Some(i % 64) } else { None }).unwrap();
                router.release(w);
            }
        });

        // scheduler tick with a large running set (null compute)
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 1 << 16,
            max_running: 256,
            token_budget: 4096,
            prefill_chunk: 512,
            queue_cap: 4096,
            workers: 1,
            ..ServeConfig::default()
        };
        let mut engine = Engine::new(
            cfg,
            Box::new(|_req: &Request| Box::new(NullBackend) as Box<dyn SeqBackend>),
        );
        let mut tick_handles = Vec::new();
        for _ in 0..256u64 {
            // keep decoding forever
            tick_handles.push(
                engine
                    .submit(Request::new(vec![0; 512]).max_new(1_000_000))
                    .expect("admission"),
            );
        }
        // drop the handles: token events are discarded at send instead of
        // queueing unboundedly across the timed iterations, keeping the
        // tick measurement steady-state
        drop(tick_handles);
        // warm into decode phase
        for _ in 0..8 {
            engine.tick();
        }
        bench("scheduler tick (256 running decodes)", 3, 100, || {
            engine.tick();
        });
        println!(
            "\nper-sequence scheduling overhead: see mean/256 — target: <1us/seq (paper's L3 must not bottleneck)"
        );
    }

    if run("prefix_cache") {
        // prefix caching: 8 RAG requests sharing a 4k-token document prefix.
        // The first request prefills and registers the prefix; the rest
        // adopt its blocks and skip both KV storage and prefill compute.
        let spec = SynthSpec::eval_base(0xCAFE);
        let mut gen = WorkloadGen::new(&spec, 0x5A5);
        let tasks = gen.rag_suite(8, 4096, 64);
        let total_prompt: u64 = tasks.iter().map(|t| t.prompt.len() as u64).sum();
        let cache_cfg = ServeConfig {
            block_size: 16,
            num_blocks: 8192,
            max_running: 8,
            token_budget: 4096,
            prefill_chunk: 512,
            queue_cap: 64,
            workers: 1,
            enable_prefix_cache: true,
            prefix_cache_blocks: 4096,
            batched_decode: true,
            ..ServeConfig::default()
        };
        let prefilled = Rc::new(Cell::new(0u64));
        let counter = prefilled.clone();
        let mut engine = Engine::new(
            cache_cfg,
            Box::new(move |_req: &Request| {
                Box::new(CountingBackend { prefilled: counter.clone(), tokens: 0 })
                    as Box<dyn SeqBackend>
            }),
        );
        let t0 = std::time::Instant::now();
        let mut rag_handles = Vec::new();
        for t in tasks.iter() {
            rag_handles.push(
                engine
                    .submit(Request::new(t.prompt.clone()).max_new(2))
                    .expect("admission"),
            );
            // run each request to completion so request 0's registered
            // prefix is available to every follower (steady-state RAG shape)
            engine.run_to_completion(&mut rag_handles);
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = &engine.metrics;
        let saved_frac = m.saved_prefill_tokens as f64 / total_prompt as f64;
        println!(
            "\nprefix caching (8 requests x {} tok, 4096-tok shared prefix):",
            tasks[0].prompt.len()
        );
        println!("  {}", m.report());
        println!(
            "  prefilled {} of {total_prompt} prompt tokens — {:.0}% prefill saved, hit rate {:.0}%, wall {wall:.3}s",
            prefilled.get(),
            saved_frac * 100.0,
            m.prefix_hit_rate() * 100.0
        );
        assert!(
            saved_frac >= 0.5,
            "prefix caching must save >= 50% of prefill tokens (got {:.0}%)",
            saved_frac * 100.0
        );
        record.push((
            "prefix_cache",
            Json::obj(vec![
                ("saved_frac", Json::num(saved_frac)),
                ("hit_rate", Json::num(m.prefix_hit_rate())),
            ]),
        ));
        engine.sched.blocks.check_invariants().unwrap();
    }

    if run("step_batched_decode") {
        // step-batched decode: 8 concurrent decoders on the real SynthLM
        // engine, batched vs. sequential.  The tick's decodes run as ONE
        // layer-major pass per model, so every weight matrix is streamed once
        // per token-step instead of once per sequence — the dominant
        // memory-bandwidth cost at small contexts.  Outputs must be
        // IDENTICAL (bitwise-equal logits => identical greedy streams).
        let mut spec = SynthSpec::eval_base(0xD0DE);
        spec.cfg.n_layers = 8;
        spec.block_starts = vec![1, 4];
        let model = Arc::new(spec.build());
        let mut gen = WorkloadGen::new(&spec, 0xD1CE);
        let prompts: Vec<Vec<u32>> = (0..8).map(|_| gen.dev_prompt(16)).collect();
        let decode_run = |batched: bool| -> (Vec<Completion>, f64) {
            let cfg = ServeConfig {
                block_size: 16,
                num_blocks: 1024,
                max_running: 8,
                token_budget: 1024,
                prefill_chunk: 128,
                queue_cap: 64,
                workers: 1,
                enable_prefix_cache: false,
                prefix_cache_blocks: 0,
                batched_decode: batched,
                ..ServeConfig::default()
            };
            let model = model.clone();
            let mut engine = Engine::new(
                cfg,
                Box::new(move |_req: &Request| {
                    Box::new(NativeBackend::new(model.clone(), 64, Box::new(DensePolicy)))
                        as Box<dyn SeqBackend>
                }),
            );
            let mut handles = Vec::new();
            for p in prompts.iter() {
                handles.push(
                    engine
                        .submit(Request::new(p.clone()).max_new(24))
                        .expect("admission"),
                );
            }
            let mut done = engine.run_to_completion(&mut handles);
            done.sort_by_key(|c| c.id);
            (done, engine.metrics.decode_tok_s())
        };
        let (seq_done, seq_tok_s) = decode_run(false);
        let (bat_done, bat_tok_s) = decode_run(true);
        for (a, b) in seq_done.iter().zip(&bat_done) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens, b.tokens,
                "batched decode must be bitwise-equivalent to sequential (req {})",
                a.id
            );
        }
        let ratio = bat_tok_s / seq_tok_s.max(1e-9);
        println!("\nstep-batched decode (8 decoders x 24 tok, 8-layer SynthLM):");
        println!(
            "  sequential {seq_tok_s:.1} tok/s  batched {bat_tok_s:.1} tok/s  ratio {ratio:.2}x  outputs identical"
        );
        assert!(
            ratio >= 1.5,
            "step-batched decode must reach >= 1.5x sequential tokens/s at batch 8 (got {ratio:.2}x)"
        );
        record.push((
            "step_batched_decode",
            Json::obj(vec![
                ("batch", Json::num(8.0)),
                ("max_new", Json::num(24.0)),
                ("n_layers", Json::num(8.0)),
                ("decode_tok_s_sequential", Json::num(seq_tok_s)),
                ("decode_tok_s_batched", Json::num(bat_tok_s)),
                ("ratio", Json::num(ratio)),
                ("outputs_identical", Json::Bool(true)),
            ]),
        ));
    }

    if run("quantized_kv") {
        // KV storage modes: f32 vs f16 vs int8 vs int4 serving on the
        // same Kascade workload.  Anchor Top-k scoring runs FUSED over
        // the compressed tiles (f16 converts per row, no dequant; the
        // integer codes use the split zero-point identity); only the
        // attended value rows of the code modes dequantize.  Records
        // peak resident KV bytes, decode throughput, and the
        // teacher-forced per-token logit divergence of every compressed
        // mode against the f32 stream.
        let mut qspec = SynthSpec::eval_base(0xBEEF);
        qspec.cfg.n_layers = 6;
        qspec.block_starts = vec![1, 3];
        let qmodel = Arc::new(qspec.build());
        let mut qgen = WorkloadGen::new(&qspec, 0xFACE);
        let qprompts: Vec<Vec<u32>> = (0..4).map(|_| qgen.dev_prompt(96)).collect();
        let mk_plan = || KascadePlan::from_anchors(6, 4, vec![0, 3], TopKRule::new(0.25, 16));
        let quant_run = |dtype: KvDtype| -> (Vec<Completion>, f64, usize, u64) {
            let cfg = ServeConfig {
                block_size: 16,
                num_blocks: 2048,
                max_running: 4,
                token_budget: 1024,
                prefill_chunk: 128,
                queue_cap: 16,
                workers: 1,
                kv_dtype: dtype,
                ..ServeConfig::default()
            };
            let model = qmodel.clone();
            let mut engine = Engine::new(
                cfg,
                Box::new(move |_req: &Request| {
                    Box::new(NativeBackend::with_dtype(
                        model.clone(),
                        256,
                        Box::new(KascadePolicy::new(mk_plan())),
                        dtype,
                    )) as Box<dyn SeqBackend>
                }),
            );
            let mut handles = Vec::new();
            for p in qprompts.iter() {
                handles.push(
                    engine
                        .submit(Request::new(p.clone()).max_new(24))
                        .expect("admission"),
                );
            }
            let mut done = engine.run_to_completion(&mut handles);
            done.sort_by_key(|c| c.id);
            (
                done,
                engine.metrics.decode_tok_s(),
                engine.metrics.peak_kv_bytes,
                engine.metrics.dequant_rows,
            )
        };
        let (f32_done, f32_tok_s, f32_bytes, _) = quant_run(KvDtype::F32);
        let (_, f16_tok_s, f16_bytes, _) = quant_run(KvDtype::F16);
        let (_, int8_tok_s, int8_bytes, int8_dequant) = quant_run(KvDtype::Int8);
        let (_, int4_tok_s, int4_bytes, int4_dequant) = quant_run(KvDtype::Int4);
        let bytes_ratio = f32_bytes as f64 / (int8_bytes as f64).max(1.0);
        let f16_bytes_ratio = f32_bytes as f64 / (f16_bytes as f64).max(1.0);
        let int4_bytes_ratio = f32_bytes as f64 / (int4_bytes as f64).max(1.0);
        let tok_s_ratio = int8_tok_s / f32_tok_s.max(1e-9);
        // teacher-forced divergence: feed the f32 run's streams to both
        // precisions so one low-margin argmax flip cannot cascade
        let rel_l2 = |a: &[f32], b: &[f32]| -> f64 {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (x, y) in a.iter().zip(b) {
                num += ((x - y) as f64).powi(2);
                den += (*x as f64).powi(2);
            }
            (num / den.max(1e-12)).sqrt()
        };
        let divergence = |dtype: KvDtype| -> f64 {
            let mut max_rel = 0.0f64;
            for (p, c) in qprompts.iter().zip(&f32_done) {
                let mut st_f = qmodel.new_state_with_dtype(256, KvDtype::F32);
                let mut st_q = qmodel.new_state_with_dtype(256, dtype);
                let mut pol_f = KascadePolicy::new(mk_plan());
                let mut pol_q = KascadePolicy::new(mk_plan());
                let (lf, _) = qmodel.prefill(p, &mut st_f, &mut pol_f, None);
                let (lq, _) = qmodel.prefill(p, &mut st_q, &mut pol_q, None);
                max_rel = max_rel.max(rel_l2(&lf, &lq));
                for &tok in &c.tokens {
                    let lf = qmodel.decode_step(tok, &mut st_f, &mut pol_f);
                    let lq = qmodel.decode_step(tok, &mut st_q, &mut pol_q);
                    max_rel = max_rel.max(rel_l2(&lf, &lq));
                }
            }
            max_rel
        };
        let max_rel = divergence(KvDtype::Int8);
        let max_rel_f16 = divergence(KvDtype::F16);
        let max_rel_int4 = divergence(KvDtype::Int4);
        // per-mode divergence bounds the headroom gates are cut against:
        // f16 carries ~11 bits of mantissa so its teacher-forced drift
        // stays orders of magnitude under 0.05; the int4 bound 1.0 is a
        // CORRELATION bound (uncorrelated logits land near sqrt(2)), not
        // an accuracy claim — int4 is the capacity-stretch mode and its
        // accuracy story is per-deployment.
        const F16_DIVERGENCE_BOUND: f64 = 0.05;
        const INT4_DIVERGENCE_BOUND: f64 = 1.0;
        let f16_divergence_headroom = F16_DIVERGENCE_BOUND / max_rel_f16.max(1e-12);
        let int4_divergence_headroom = INT4_DIVERGENCE_BOUND / max_rel_int4.max(1e-12);
        println!("\nquantized KV (4 decoders x 24 tok, 6-layer SynthLM, Kascade policy):");
        println!(
            "  peak KV bytes f32 {f32_bytes}  f16 {f16_bytes} ({f16_bytes_ratio:.2}x)  \
             int8 {int8_bytes} ({bytes_ratio:.2}x)  int4 {int4_bytes} ({int4_bytes_ratio:.2}x)"
        );
        println!(
            "  decode f32 {f32_tok_s:.1}  f16 {f16_tok_s:.1}  int8 {int8_tok_s:.1}  \
             int4 {int4_tok_s:.1} tok/s  (int8/f32 ratio {tok_s_ratio:.2}x)"
        );
        println!(
            "  max per-token logit divergence (teacher-forced, rel L2): \
             f16 {max_rel_f16:.5}  int8 {max_rel:.4}  int4 {max_rel_int4:.4}  \
             dequant rows int8 {int8_dequant} int4 {int4_dequant}"
        );
        assert!(
            bytes_ratio >= 1.8,
            "int8 KV must cut peak resident bytes >= 1.8x (got {bytes_ratio:.2}x)"
        );
        assert!(
            f16_bytes_ratio >= 1.5,
            "f16 KV must cut peak resident bytes >= 1.5x (got {f16_bytes_ratio:.2}x)"
        );
        assert!(
            int4_bytes_ratio >= 2.5,
            "int4 KV must cut peak resident bytes >= 2.5x (got {int4_bytes_ratio:.2}x)"
        );
        assert!(
            max_rel <= 0.15,
            "int8 per-token logit divergence {max_rel:.4} exceeds the 0.15 bound"
        );
        assert!(
            max_rel_f16 <= F16_DIVERGENCE_BOUND,
            "f16 per-token logit divergence {max_rel_f16:.5} exceeds the {F16_DIVERGENCE_BOUND} bound"
        );
        assert!(
            max_rel_int4 <= INT4_DIVERGENCE_BOUND,
            "int4 per-token logit divergence {max_rel_int4:.4} exceeds the {INT4_DIVERGENCE_BOUND} bound"
        );
        record.push((
            "quantized_kv",
            Json::obj(vec![
                ("batch", Json::num(4.0)),
                ("max_new", Json::num(24.0)),
                ("n_layers", Json::num(6.0)),
                ("peak_kv_bytes_f32", Json::num(f32_bytes as f64)),
                ("peak_kv_bytes_f16", Json::num(f16_bytes as f64)),
                ("peak_kv_bytes_int8", Json::num(int8_bytes as f64)),
                ("peak_kv_bytes_int4", Json::num(int4_bytes as f64)),
                ("kv_bytes_ratio", Json::num(bytes_ratio)),
                ("f16_kv_bytes_ratio", Json::num(f16_bytes_ratio)),
                ("int4_kv_bytes_ratio", Json::num(int4_bytes_ratio)),
                ("decode_tok_s_f32", Json::num(f32_tok_s)),
                ("decode_tok_s_f16", Json::num(f16_tok_s)),
                ("decode_tok_s_int8", Json::num(int8_tok_s)),
                ("decode_tok_s_int4", Json::num(int4_tok_s)),
                ("decode_tok_s_ratio", Json::num(tok_s_ratio)),
                ("max_rel_logit_divergence", Json::num(max_rel)),
                ("max_rel_logit_divergence_f16", Json::num(max_rel_f16)),
                ("max_rel_logit_divergence_int4", Json::num(max_rel_int4)),
                ("f16_divergence_headroom", Json::num(f16_divergence_headroom)),
                ("int4_divergence_headroom", Json::num(int4_divergence_headroom)),
                ("dequant_rows", Json::num(int8_dequant as f64)),
                ("dequant_rows_int4", Json::num(int4_dequant as f64)),
            ]),
        ));
    }

    if run("simd_kernels") {
        // simd-vs-scalar tile kernels (docs/perf.md § SIMD): the two
        // tile-major hot loops (Top-k scoring, weighted-value
        // accumulation) timed at the detected dispatch level and again
        // forced to the scalar reference, for every KV storage mode.
        // The gated metric is the MINIMUM speedup over all (dtype x
        // kernel) cells — baseline 0.9, i.e. "vectorized dispatch is
        // never materially slower than scalar".  On hosts where detect()
        // resolves to Scalar both timings walk the same code path and
        // every cell sits at ~1.0, so the gate still holds.
        let detected = kascade::simd::detect();
        const D: usize = 64;
        const NKV: usize = 2;
        const TILES: usize = 64;
        const CAP: usize = TILES * 16;
        const PASSES: usize = 50;
        const REPS: usize = 7;
        let build = |dtype: KvDtype| -> KvCache {
            let mut c = KvCache::with_opts(NKV, D, CAP, 16, dtype);
            let mut rng = Rng::new(0x51D0 + dtype as u64);
            for _ in 0..CAP {
                let k: Vec<f32> = (0..NKV * D).map(|_| rng.uniform() * 2.0 - 1.0).collect();
                let v: Vec<f32> = (0..NKV * D).map(|_| rng.uniform() * 2.0 - 1.0).collect();
                c.push(&k, &v);
            }
            c
        };
        let mut qrng = Rng::new(0xBEA7);
        let q: Vec<f32> = (0..D).map(|_| qrng.uniform() * 2.0 - 1.0).collect();
        let w: Vec<f32> = (0..16).map(|_| qrng.uniform() * 0.1 + 1e-3).collect();
        // best-of-REPS wall time of PASSES full sweeps over every tile
        // of every head — min, not mean, so a scheduler hiccup on a
        // shared runner can't fake a regression
        let time_kernel = |c: &KvCache, attend: bool| -> f64 {
            let mut best = f64::INFINITY;
            let mut scores = vec![0.0f32; 16];
            let mut acc = vec![0.0f32; D];
            for _ in 0..REPS {
                let t = std::time::Instant::now();
                for _ in 0..PASSES {
                    for h in 0..NKV {
                        for tile in 0..TILES {
                            if attend {
                                c.attend_tile(h, tile, CAP, &w, &mut acc);
                            } else {
                                c.score_tile(h, tile, CAP, &q, 0.125, &mut scores);
                            }
                        }
                    }
                }
                best = best.min(t.elapsed().as_secs_f64());
            }
            std::hint::black_box((&scores, &acc));
            best
        };
        let mut min_cell = f64::INFINITY;
        let mut cells: Vec<(&str, Json)> = Vec::new();
        println!("\nsimd kernels (level {}, {} tiles x {} heads, d={}):", detected.label(), TILES, NKV, D);
        println!("| dtype | kernel | scalar (ms) | {} (ms) | speedup |", detected.label());
        println!("|---|---|---|---|---|");
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8, KvDtype::Int4] {
            let mut cache = build(dtype);
            for attend in [false, true] {
                let kernel = if attend { "attend_tile" } else { "score_tile" };
                cache.set_simd_level(detected);
                let t_simd = time_kernel(&cache, attend);
                cache.set_simd_level(kascade::simd::SimdLevel::Scalar);
                let t_scalar = time_kernel(&cache, attend);
                let speedup = t_scalar / t_simd.max(1e-12);
                min_cell = min_cell.min(speedup);
                println!(
                    "| {} | {} | {:.3} | {:.3} | {:.2}x |",
                    dtype.label(),
                    kernel,
                    t_scalar * 1e3,
                    t_simd * 1e3,
                    speedup
                );
                let key = match (dtype, attend) {
                    (KvDtype::F32, false) => "f32_score_tile_speedup",
                    (KvDtype::F32, true) => "f32_attend_tile_speedup",
                    (KvDtype::F16, false) => "f16_score_tile_speedup",
                    (KvDtype::F16, true) => "f16_attend_tile_speedup",
                    (KvDtype::Int8, false) => "int8_score_tile_speedup",
                    (KvDtype::Int8, true) => "int8_attend_tile_speedup",
                    (KvDtype::Int4, false) => "int4_score_tile_speedup",
                    (KvDtype::Int4, true) => "int4_attend_tile_speedup",
                };
                cells.push((key, Json::num(speedup)));
            }
        }
        println!("  min cell speedup {min_cell:.2}x");
        assert!(
            min_cell >= 0.5,
            "a simd kernel cell collapsed to {min_cell:.2}x of scalar"
        );
        cells.push(("level", Json::str(detected.label())));
        cells.push(("min_cell_speedup", Json::num(min_cell)));
        record.push(("simd_kernels", Json::obj(cells)));
    }

    if run("streaming") {
        // streaming sessions: (a) handle-observed TTFT vs engine-observed
        // TTFT — the gap is the event-delivery overhead a client actually
        // sees, recorded as a fidelity ratio (engine/handle, ~1.0 when
        // events arrive the tick they are produced); (b) cancellation
        // reclaim — mid-decode cancel() must release every KV block within
        // ONE tick, with the wall latency recorded.
        let mut sspec = SynthSpec::eval_base(0x51D);
        sspec.cfg.n_layers = 4;
        sspec.block_starts = vec![1];
        let smodel = Arc::new(sspec.build());
        let mut sgen = WorkloadGen::new(&sspec, 0x717);
        let sprompts: Vec<Vec<u32>> = (0..6).map(|_| sgen.dev_prompt(256)).collect();
        let scfg = ServeConfig {
            block_size: 16,
            num_blocks: 2048,
            max_running: 8,
            token_budget: 512,
            prefill_chunk: 128,
            queue_cap: 64,
            workers: 1,
            ..ServeConfig::default()
        };
        let stream_factory = |model: Arc<kascade::model::Model>| {
            Box::new(move |_req: &Request| {
                Box::new(NativeBackend::new(model.clone(), 512, Box::new(DensePolicy)))
                    as Box<dyn SeqBackend>
            })
        };
        let mut engine = Engine::new(scfg.clone(), stream_factory(smodel.clone()));
        let mut handles = Vec::new();
        for p in &sprompts {
            handles.push(engine.submit(Request::new(p.clone()).max_new(16)).expect("admission"));
        }
        let mut streamed: Vec<Vec<u32>> = (0..handles.len()).map(|_| Vec::new()).collect();
        let mut completions: Vec<Completion> = Vec::new();
        while !engine.idle() {
            engine.tick();
            for (i, h) in handles.iter_mut().enumerate() {
                while let Some(ev) = h.try_next() {
                    match ev {
                        Event::Token { tok, .. } => streamed[i].push(tok),
                        Event::Done(c) => completions.push(c),
                        _ => {}
                    }
                }
            }
        }
        assert_eq!(completions.len(), sprompts.len());
        for c in &completions {
            assert_eq!(
                streamed[c.id as usize], c.tokens,
                "streamed tokens must reassemble the completion (req {})",
                c.id
            );
        }
        let handle_ttft_p50 = engine.metrics.streamed_ttft_percentile(50.0);
        let engine_ttft_p50 = engine.metrics.ttft_us.percentile(50.0);
        let ttft_fidelity = (engine_ttft_p50 / handle_ttft_p50.max(1e-9)).min(1.0);

        // cancellation reclaim
        let mut engine = Engine::new(scfg, stream_factory(smodel));
        let mut handles = Vec::new();
        for p in &sprompts {
            handles.push(engine.submit(Request::new(p.clone()).max_new(10_000)).expect("admission"));
        }
        // run everyone into decode
        while engine.metrics.decode_tokens < 2 * sprompts.len() as u64 {
            engine.tick();
        }
        let blocks_held = engine.sched.blocks.used();
        assert!(blocks_held > 0);
        for h in &handles {
            h.cancel();
        }
        let t0 = std::time::Instant::now();
        engine.tick();
        let cancel_reclaim_us = t0.elapsed().as_secs_f64() * 1e6;
        let reclaim_within_one_tick = if engine.sched.blocks.used() == 0 { 1.0 } else { 0.0 };
        assert_eq!(
            engine.sched.blocks.used(),
            0,
            "mid-stream cancel must release every KV block within one tick"
        );
        engine.sched.blocks.check_invariants().unwrap();
        assert_eq!(engine.metrics.cancelled, sprompts.len() as u64);
        println!("\nstreaming sessions (6 requests x 256-tok prompts, 4-layer SynthLM):");
        println!(
            "  ttft handle p50 {handle_ttft_p50:.0}us  engine p50 {engine_ttft_p50:.0}us  \
             fidelity {ttft_fidelity:.3}"
        );
        println!(
            "  cancel: {blocks_held} blocks reclaimed in {cancel_reclaim_us:.0}us (one tick)"
        );
        record.push((
            "streaming",
            Json::obj(vec![
                ("requests", Json::num(sprompts.len() as f64)),
                ("handle_ttft_p50_us", Json::num(handle_ttft_p50)),
                ("engine_ttft_p50_us", Json::num(engine_ttft_p50)),
                ("ttft_fidelity", Json::num(ttft_fidelity)),
                ("cancel_reclaim_us", Json::num(cancel_reclaim_us)),
                ("reclaim_within_one_tick", Json::num(reclaim_within_one_tick)),
            ]),
        ));
    }

    if run("parallel_tick") {
        // parallel tick: the same step-batched scenario sharded over the
        // engine's worker pool (ServeConfig::num_threads), on a heavier model
        // so attention dominates scheduling.  Output streams must be BITWISE
        // identical to the single-threaded engine; the tokens/s ratio is
        // recorded for the perf trajectory (and gated not to collapse).
        let mut pspec = SynthSpec::eval_base(0xFA57);
        pspec.cfg.n_layers = 6;
        pspec.block_starts = vec![1, 3];
        let pmodel = Arc::new(pspec.build());
        let mut pgen = WorkloadGen::new(&pspec, 0xFA58);
        let pprompts: Vec<Vec<u32>> = (0..8).map(|_| pgen.dev_prompt(384)).collect();
        let mk_pplan = || KascadePlan::from_anchors(6, 4, vec![0, 3], TopKRule::new(0.25, 16));
        let parallel_run = |threads: usize| -> (Vec<Completion>, f64) {
            let cfg = ServeConfig {
                block_size: 16,
                num_blocks: 4096,
                max_running: 8,
                token_budget: 1024,
                prefill_chunk: 128,
                queue_cap: 64,
                workers: 1,
                num_threads: threads,
                ..ServeConfig::default()
            };
            let model = pmodel.clone();
            let mut engine = Engine::new(
                cfg,
                Box::new(move |_req: &Request| {
                    Box::new(NativeBackend::new(
                        model.clone(),
                        512,
                        Box::new(KascadePolicy::new(mk_pplan())),
                    )) as Box<dyn SeqBackend>
                }),
            );
            let mut handles = Vec::new();
            for p in pprompts.iter() {
                handles.push(engine.submit(Request::new(p.clone()).max_new(32)).expect("admission"));
            }
            let mut done = engine.run_to_completion(&mut handles);
            done.sort_by_key(|c| c.id);
            (done, engine.metrics.decode_tok_s())
        };
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let par_threads = cores.clamp(2, 4);
        let (one_done, one_tok_s) = parallel_run(1);
        let (par_done, par_tok_s) = parallel_run(par_threads);
        for (a, b) in one_done.iter().zip(&par_done) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens, b.tokens,
                "parallel tick must be bitwise-equivalent to single-threaded (req {})",
                a.id
            );
        }
        let par_ratio = par_tok_s / one_tok_s.max(1e-9);
        println!(
            "\nparallel tick (8 Kascade decoders x 32 tok, 6-layer SynthLM, \
             {par_threads} threads on {cores} cores):"
        );
        println!(
            "  1-thread {one_tok_s:.1} tok/s  {par_threads}-thread {par_tok_s:.1} tok/s  \
             ratio {par_ratio:.2}x  outputs identical"
        );
        if cores >= 2 {
            assert!(
                par_ratio >= 0.5,
                "parallel tick collapsed to {par_ratio:.2}x of single-threaded decode tok/s"
            );
        }
        record.push((
            "parallel_tick",
            Json::obj(vec![
                ("batch", Json::num(8.0)),
                ("max_new", Json::num(32.0)),
                ("n_layers", Json::num(6.0)),
                ("threads", Json::num(par_threads as f64)),
                ("host_cores", Json::num(cores as f64)),
                ("decode_tok_s_single", Json::num(one_tok_s)),
                ("decode_tok_s_parallel", Json::num(par_tok_s)),
                ("ratio_vs_single_thread", Json::num(par_ratio)),
                ("outputs_identical", Json::num(1.0)),
            ]),
        ));
    }

    if run("slo_traffic") {
        // SLO-gated traffic: a seeded bursty multi-tenant stream (RAG /
        // agentic / summarization mix, heavy-tailed lengths) over the
        // null-compute engine so the numbers isolate the scheduling and
        // event-delivery surface.  Mid-run a 512k-token prompt lands and
        // chunk-prefills under `decode_guard_prefill_tokens` while the
        // traffic keeps decoding — the scenario both measures the
        // TTFT/TPOT percentile surface against wall-clock SLOs and
        // checks the guard actually bounded per-tick prefill.  The SLO
        // targets are the deployment's `ServeConfig` knobs
        // (`ttft_slo_ms` / `tpot_slo_ms`), not bench-local constants.
        // The CI gate reads headroom ratios (slo / p95, higher is
        // better): baseline 1.0 means "SLO exactly met", so the gate's
        // 10% tolerance reads as "SLO held with 10% grace".
        const GUARD: usize = 128;
        const BIG: usize = 524_288; // 512k tokens
        const ARRIVAL_TICKS: usize = 300;
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 40960, // 32768 for the 512k prompt + traffic working set
            max_running: 16,
            token_budget: 1024,
            prefill_chunk: 256,
            queue_cap: 1024,
            workers: 1,
            fair_share: true,
            decode_guard_prefill_tokens: Some(GUARD),
            ..ServeConfig::default()
        };
        let slo_ttft_ms = cfg.ttft_slo_ms;
        let slo_tpot_ms = cfg.tpot_slo_ms;
        let mut engine = Engine::new(
            cfg,
            Box::new(|_req: &Request| Box::new(NullBackend) as Box<dyn SeqBackend>),
        );
        let mut gen = TrafficGen::new(TrafficSpec {
            seed: 0xB0057,
            base_rate: 1.0,
            prompt_cap: 512,
            ..TrafficSpec::default()
        });
        let mut handles = Vec::new();
        let mut rejected = 0u64;
        let t0 = std::time::Instant::now();
        // phase A: build a live multi-tenant decode population
        for _ in 0..40 {
            for r in gen.next_tick() {
                match engine.submit(Request::new(r.prompt).max_new(r.max_new).tenant(r.tenant)) {
                    Ok(h) => handles.push(h),
                    Err(_) => rejected += 1,
                }
            }
            engine.tick();
        }
        // phase B: the 128k prompt lands mid-traffic and chunk-prefills
        // under the guard while arrivals continue
        let big = engine
            .submit(Request::new(vec![3; BIG]).max_new(4).tenant(9))
            .expect("big admission");
        let big_id = big.id();
        handles.push(big);
        let mut tick_no = 40usize;
        let mut guard_violations = 0u64;
        let mut last_done = 0usize;
        loop {
            match engine.seqs.get(&big_id).map(|s| s.phase) {
                Some(SeqPhase::Decoding) | Some(SeqPhase::Finished) | None => break,
                _ => {}
            }
            if tick_no < ARRIVAL_TICKS {
                for r in gen.next_tick() {
                    match engine.submit(Request::new(r.prompt).max_new(r.max_new).tenant(r.tenant))
                    {
                        Ok(h) => handles.push(h),
                        Err(_) => rejected += 1,
                    }
                }
            }
            // the guard only binds on ticks that schedule decodes
            let live_decoders = engine
                .seqs
                .iter()
                .filter(|(id, s)| **id != big_id && matches!(s.phase, SeqPhase::Decoding))
                .count();
            engine.tick();
            tick_no += 1;
            let done = match engine.seqs.get(&big_id).map(|s| s.phase) {
                Some(SeqPhase::Prefilling { done }) => done,
                Some(SeqPhase::Decoding) | Some(SeqPhase::Finished) => BIG,
                _ => 0,
            };
            if live_decoders > 0 && done.saturating_sub(last_done) > GUARD {
                guard_violations += 1;
            }
            last_done = done;
            assert!(tick_no < 60_000, "512k guarded prefill never completed");
        }
        // phase C: drain everything (run_to_completion only collects
        // completions produced while it ticks — events that landed during
        // the arrival loop are still queued on their handles)
        let mut done = engine.run_to_completion(&mut handles);
        for h in &mut handles {
            while let Some(ev) = h.try_next() {
                if let Event::Done(c) = ev {
                    done.push(c);
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = &engine.metrics;
        let ttft_p50 = m.ttft_percentile(50.0) / 1e3;
        let ttft_p95 = m.ttft_percentile(95.0) / 1e3;
        let ttft_p99 = m.ttft_percentile(99.0) / 1e3;
        let tpot_p50 = m.tpot_percentile(50.0) / 1e3;
        let tpot_p95 = m.tpot_percentile(95.0) / 1e3;
        let tpot_p99 = m.tpot_percentile(99.0) / 1e3;
        let ttft_p95_headroom = slo_ttft_ms / ttft_p95.max(1e-9);
        let tpot_p95_headroom = slo_tpot_ms / tpot_p95.max(1e-9);
        let guard_held = if guard_violations == 0 { 1.0 } else { 0.0 };
        println!(
            "\nslo_traffic ({} completions, {rejected} rejected, 512k prefill over {} guarded ticks, wall {wall:.2}s):",
            done.len(),
            tick_no - 40
        );
        println!("  {}", m.report());
        println!(
            "  ttft p50 {ttft_p50:.2}ms p95 {ttft_p95:.2}ms p99 {ttft_p99:.2}ms \
             (slo {slo_ttft_ms}ms, headroom {ttft_p95_headroom:.1}x)"
        );
        println!(
            "  tpot p50 {tpot_p50:.3}ms p95 {tpot_p95:.3}ms p99 {tpot_p99:.3}ms \
             (slo {slo_tpot_ms}ms, headroom {tpot_p95_headroom:.1}x)  guard_held {guard_held}"
        );
        assert!(done.len() >= 50, "traffic produced only {} completions", done.len());
        assert_eq!(
            guard_violations, 0,
            "decode-guard violated (prefill outran the {GUARD}-token cap on a decode tick)"
        );
        assert!(
            ttft_p95_headroom >= 1.0,
            "TTFT p95 {ttft_p95:.2}ms breaches the {slo_ttft_ms}ms SLO"
        );
        assert!(
            tpot_p95_headroom >= 1.0,
            "TPOT p95 {tpot_p95:.3}ms breaches the {slo_tpot_ms}ms SLO"
        );
        engine.sched.blocks.check_invariants().unwrap();
        record.push((
            "slo_traffic",
            Json::obj(vec![
                ("completions", Json::num(done.len() as f64)),
                ("rejected", Json::num(rejected as f64)),
                ("arrival_ticks", Json::num(ARRIVAL_TICKS as f64)),
                ("big_prefill_tokens", Json::num(BIG as f64)),
                ("decode_guard_prefill_tokens", Json::num(GUARD as f64)),
                ("slo_ttft_ms", Json::num(slo_ttft_ms)),
                ("slo_tpot_ms", Json::num(slo_tpot_ms)),
                ("ttft_p50_ms", Json::num(ttft_p50)),
                ("ttft_p95_ms", Json::num(ttft_p95)),
                ("ttft_p99_ms", Json::num(ttft_p99)),
                ("tpot_p50_ms", Json::num(tpot_p50)),
                ("tpot_p95_ms", Json::num(tpot_p95)),
                ("tpot_p99_ms", Json::num(tpot_p99)),
                ("ttft_p95_headroom", Json::num(ttft_p95_headroom)),
                ("tpot_p95_headroom", Json::num(tpot_p95_headroom)),
                ("guard_held", Json::num(guard_held)),
                ("wall_s", Json::num(wall)),
            ]),
        ));
    }

    if run("long_context_tiered") {
        // tiered KV at long context (docs/kv-tiers.md): a 512Ki-token
        // Kascade context decoded with the reuse layers' KV under a 25%
        // hot-tile budget, cold tiles spilled to a file-backed store.
        // The context is seeded by direct K/V pushes — the identity
        // property only needs identical cache CONTENTS, and a full 512k
        // prefill is O(T^2) attention this scenario does not measure.
        // Gates: peak resident KV bytes of the tiered layers stay under
        // the computed tier budget, and the tiered greedy stream is
        // IDENTICAL to the all-resident int8 run.
        const T: usize = 512 * 1024;
        const PS: usize = 16; // quantization-tile positions (new_state default)
        const NKV: usize = 2;
        const DH: usize = 8;
        const STEPS: usize = 32;
        let n_tiles = T / PS;
        let budget = n_tiles / 4; // 25% of the seeded context's tiles
        let lcfg = ModelConfig {
            n_layers: 4,
            d_model: 32,
            n_q_heads: 4,
            n_kv_heads: NKV,
            d_head: DH,
            d_ff: 64,
            vocab: 64,
            rope_theta: 10000.0,
            rope: true,
        };
        let mut w = Weights::zeros(&lcfg);
        let mut wr = Rng::new(0x10C7);
        wr.fill_normal(&mut w.w_e, 0.3);
        for lw in &mut w.layers {
            wr.fill_normal(&mut lw.wq, 0.18);
            wr.fill_normal(&mut lw.wk, 0.18);
            wr.fill_normal(&mut lw.wv, 0.18);
            wr.fill_normal(&mut lw.wo, 0.18);
            wr.fill_normal(&mut lw.w1, 0.18);
            wr.fill_normal(&mut lw.w3, 0.18);
            wr.fill_normal(&mut lw.w2, 0.12);
        }
        wr.fill_normal(&mut w.w_u, 0.18);
        let tmodel = Model::new(lcfg, w);
        let mk_tplan = || -> Box<dyn SparsePolicy> {
            Box::new(KascadePolicy::new(KascadePlan::from_anchors(
                4,
                NKV,
                vec![0, 2],
                TopKRule::new(0.005, 64),
            )))
        };
        let fill = |st: &mut SeqState| {
            let mut k = vec![0.0f32; NKV * DH];
            let mut v = vec![0.0f32; NKV * DH];
            for layer in 0..4 {
                let mut r = Rng::new(0xF111_0000 + layer as u64);
                for _ in 0..T {
                    r.fill_normal(&mut k, 0.5);
                    r.fill_normal(&mut v, 0.5);
                    st.caches[layer].push(&k, &v);
                }
            }
            st.pos = T;
        };
        std::fs::create_dir_all("results").expect("results dir");
        let spill_path = "results/tier_spill.kvsp";
        let _ = std::fs::remove_file(spill_path);
        let store = shared_store(FileTileStore::open(spill_path).expect("open spill store"));
        let mut pol_t = mk_tplan();
        let mut pol_f = mk_tplan();
        let cap = T + STEPS + PS;
        let mut st_t = tmodel.new_state_tiered(cap, pol_t.as_ref(), TierParams::new(budget), &store);
        let mut st_f = tmodel.new_state_with_dtype(cap, KvDtype::Int8);
        let t0 = std::time::Instant::now();
        fill(&mut st_t);
        fill(&mut st_f);
        let fill_s = t0.elapsed().as_secs_f64();
        // greedy decode; samples the tiered layers' resident bytes every
        // step so demand-promotion overshoot cannot hide from the gate
        let decode = |m: &Model,
                      st: &mut SeqState,
                      pol: &mut Box<dyn SparsePolicy>,
                      peak: &mut usize|
         -> (Vec<u32>, f64) {
            let mut toks = Vec::new();
            let mut tok = 1u32;
            let t0 = std::time::Instant::now();
            for _ in 0..STEPS {
                let l = m.decode_step(tok, st, pol.as_mut());
                tok = argmax(&l) as u32;
                toks.push(tok);
                let b: usize =
                    st.caches.iter().filter(|c| c.is_tiered()).map(|c| c.kv_bytes()).sum();
                *peak = (*peak).max(b);
            }
            (toks, STEPS as f64 / t0.elapsed().as_secs_f64())
        };
        let mut peak_tiered: usize =
            st_t.caches.iter().filter(|c| c.is_tiered()).map(|c| c.kv_bytes()).sum();
        let mut unused = 0usize;
        let (toks_f, tok_s_f) = decode(&tmodel, &mut st_f, &mut pol_f, &mut unused);
        let (toks_t, tok_s_t) = decode(&tmodel, &mut st_t, &mut pol_t, &mut peak_tiered);
        assert_eq!(
            toks_t, toks_f,
            "tiered decode must be bitwise-identical to all-resident int8"
        );
        // computed byte budget for the two tiered reuse layers: hot arena
        // at the slot budget + f32 staging tail + per-tile affine params
        // + warm int4 shadows at the warm budget (= hot budget)
        let td = PS * DH;
        let tiles_max = (T + STEPS) / PS;
        let budget_bytes_per_layer = budget * 2 * NKV * td // int8 K+V hot slots
            + PS * NKV * DH * 2 * 4                        // f32 staging tail
            + tiles_max * NKV * 16                         // per-tile (scale, zero) x K,V
            + budget * (NKV * td + NKV * 16);              // warm shadows + affines
        let budget_bytes = 2 * budget_bytes_per_layer;
        assert!(
            peak_tiered <= budget_bytes,
            "tiered layers peaked at {peak_tiered} resident KV bytes, over the {budget_bytes} budget"
        );
        for l in [1usize, 3] {
            assert!(
                st_t.caches[l].hot_tiles() <= budget,
                "layer {l} holds {} hot tiles over the {budget} budget",
                st_t.caches[l].hot_tiles()
            );
        }
        let mut tstats = TierStats::default();
        for c in &mut st_t.caches {
            tstats.merge(&c.take_tier_stats());
        }
        let ensured = tstats.prefetch_hits + tstats.prefetch_misses;
        let hit_rate = tstats.prefetch_hits as f64 / (ensured as f64).max(1.0);
        let flat_reuse: usize = [1usize, 3].iter().map(|&l| st_f.caches[l].kv_bytes()).sum();
        let savings = flat_reuse as f64 / (peak_tiered as f64).max(1.0);
        let tok_s_ratio = tok_s_t / tok_s_f.max(1e-9);
        let spill_bytes = store.lock().expect("store lock").payload_bytes();
        assert!(
            savings >= 1.8,
            "25% hot budget must cut reuse-layer resident bytes >= 1.8x (got {savings:.2}x)"
        );
        println!("\nlong-context tiered KV (512Ki-token Kascade decode, 25% hot budget):");
        println!(
            "  reuse-layer KV bytes: all-resident {flat_reuse}  tiered peak {peak_tiered} \
             (budget {budget_bytes}) — {savings:.2}x smaller, outputs identical"
        );
        println!(
            "  decode all-resident {tok_s_f:.1} tok/s  tiered {tok_s_t:.1} tok/s  \
             ratio {tok_s_ratio:.2}x  prefetch hit rate {:.0}%  spill file {spill_bytes} B  \
             (context seeded in {fill_s:.1}s)",
            hit_rate * 100.0
        );
        record.push((
            "long_context_tiered",
            Json::obj(vec![
                ("context_tokens", Json::num(T as f64)),
                ("decode_steps", Json::num(STEPS as f64)),
                ("hot_tile_budget", Json::num(budget as f64)),
                ("peak_resident_kv_bytes", Json::num(peak_tiered as f64)),
                ("budget_kv_bytes", Json::num(budget_bytes as f64)),
                ("all_resident_kv_bytes", Json::num(flat_reuse as f64)),
                ("resident_savings", Json::num(savings)),
                ("peak_under_budget", Json::num(1.0)),
                ("outputs_identical", Json::num(1.0)),
                ("decode_tok_s_resident", Json::num(tok_s_f)),
                ("decode_tok_s_tiered", Json::num(tok_s_t)),
                ("decode_tok_s_ratio", Json::num(tok_s_ratio)),
                ("prefetch_hit_rate", Json::num(hit_rate)),
                ("tiles_promoted", Json::num(tstats.tiles_promoted as f64)),
                ("tiles_demoted", Json::num(tstats.tiles_demoted as f64)),
                ("spill_file_bytes", Json::num(spill_bytes as f64)),
            ]),
        ));
    }

    if run("slo_traffic_server") {
        // the SLO traffic harness through the worker boundary
        // (docs/serving.md): the same seeded bursty multi-tenant stream,
        // but submitted to a free-running 2-worker `Server` over its
        // channel boundary instead of ticking an in-process `Engine`, so
        // the numbers cover the full submit → stream → complete
        // round-trip.  Tenants pin to workers by session hash the way
        // the gateway pins agentic flows; the per-worker metrics merge
        // into one percentile surface via `ServeMetrics::merge` and gate
        // against the same per-deployment `ServeConfig` SLO knobs as
        // `slo_traffic`.
        const ARRIVAL_TICKS: usize = 120;
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 8192,
            max_running: 16,
            token_budget: 1024,
            prefill_chunk: 256,
            queue_cap: 1024,
            workers: 2,
            fair_share: true,
            decode_guard_prefill_tokens: Some(128),
            ..ServeConfig::default()
        };
        let slo_ttft_ms = cfg.ttft_slo_ms;
        let slo_tpot_ms = cfg.tpot_slo_ms;
        let factory = || -> BackendFactory {
            Box::new(|_req: &Request| Box::new(NullBackend) as Box<dyn SeqBackend>)
        };
        let mut srv = Server::start(cfg, vec![factory(), factory()]);
        let mut gen = TrafficGen::new(TrafficSpec {
            seed: 0xB0058,
            base_rate: 1.0,
            prompt_cap: 512,
            ..TrafficSpec::default()
        });
        let mut handles = Vec::new();
        let mut rejected = 0u64;
        let t0 = std::time::Instant::now();
        for _ in 0..ARRIVAL_TICKS {
            for r in gen.next_tick() {
                let session = Some(u64::from(r.tenant));
                match srv
                    .submit(Request::new(r.prompt).max_new(r.max_new).tenant(r.tenant), session)
                {
                    Ok(h) => handles.push(h),
                    Err(_) => rejected += 1,
                }
            }
        }
        let submitted = handles.len();
        let mut completions = 0u64;
        let mut failed = 0u64;
        for h in &mut handles {
            match h.wait(std::time::Duration::from_secs(120)) {
                Ok(_) => completions += 1,
                Err(_) => failed += 1,
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let parts = srv.shutdown();
        let m = ServeMetrics::merge(&parts);
        let ttft_p50 = m.ttft_percentile(50.0) / 1e3;
        let ttft_p95 = m.ttft_percentile(95.0) / 1e3;
        let tpot_p50 = m.tpot_percentile(50.0) / 1e3;
        let tpot_p95 = m.tpot_percentile(95.0) / 1e3;
        let streamed_ttft_p95 = m.streamed_ttft_percentile(95.0) / 1e3;
        let ttft_p95_headroom = slo_ttft_ms / ttft_p95.max(1e-9);
        let tpot_p95_headroom = slo_tpot_ms / tpot_p95.max(1e-9);
        let req_s = completions as f64 / wall.max(1e-9);
        println!(
            "\nslo_traffic_server ({submitted} submitted over 2 workers, {completions} \
             completions, {rejected} rejected, wall {wall:.2}s):"
        );
        println!("  {}", m.report());
        println!(
            "  {req_s:.0} req/s  engine ttft p50 {ttft_p50:.2}ms p95 {ttft_p95:.2}ms \
             (headroom {ttft_p95_headroom:.1}x)  tpot p95 {tpot_p95:.3}ms \
             (headroom {tpot_p95_headroom:.1}x)  streamed ttft p95 {streamed_ttft_p95:.2}ms"
        );
        assert_eq!(failed, 0, "{failed} requests failed crossing the worker boundary");
        assert!(completions >= 50, "traffic produced only {completions} completions");
        assert_eq!(m.threads, 2, "merge must account for both workers");
        assert!(
            ttft_p95_headroom >= 1.0,
            "TTFT p95 {ttft_p95:.2}ms breaches the {slo_ttft_ms}ms SLO over the worker boundary"
        );
        assert!(
            tpot_p95_headroom >= 1.0,
            "TPOT p95 {tpot_p95:.3}ms breaches the {slo_tpot_ms}ms SLO over the worker boundary"
        );
        record.push((
            "slo_traffic_server",
            Json::obj(vec![
                ("workers", Json::num(2.0)),
                ("arrival_ticks", Json::num(ARRIVAL_TICKS as f64)),
                ("submitted", Json::num(submitted as f64)),
                ("completions", Json::Num(completions as f64)),
                ("rejected", Json::Num(rejected as f64)),
                ("failed", Json::Num(failed as f64)),
                ("requests_per_s", Json::num(req_s)),
                ("slo_ttft_ms", Json::num(slo_ttft_ms)),
                ("slo_tpot_ms", Json::num(slo_tpot_ms)),
                ("ttft_p50_ms", Json::num(ttft_p50)),
                ("ttft_p95_ms", Json::num(ttft_p95)),
                ("tpot_p50_ms", Json::num(tpot_p50)),
                ("tpot_p95_ms", Json::num(tpot_p95)),
                ("streamed_ttft_p95_ms", Json::num(streamed_ttft_p95)),
                ("ttft_p95_headroom", Json::num(ttft_p95_headroom)),
                ("tpot_p95_headroom", Json::num(tpot_p95_headroom)),
                ("tokens_out", Json::Num(m.tokens_out as f64)),
                ("wall_s", Json::num(wall)),
            ]),
        ));
    }

    if run("gateway") {
        // the HTTP front end (docs/gateway.md): streamed generations over
        // loopback through a 2-replica gateway with prefix-affinity
        // routing.  Shared-prefix traffic (3 groups, unique tails) lets
        // the ChainSummary scorer keep each group home after one warm-up
        // miss, so the scenario measures the full per-request HTTP cost
        // (connect, POST, NDJSON chunked stream, teardown) and checks the
        // fleet actually banked prefix hits through the front end.
        struct ForkNull {
            tokens: usize,
        }
        impl SeqBackend for ForkNull {
            fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
                self.tokens += tokens.len();
                Some(vec![0.0, 1.0])
            }
            fn decode(&mut self, _token: u32) -> Vec<f32> {
                self.tokens += 1;
                vec![0.0, 1.0]
            }
            fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
                (tokens <= self.tokens)
                    .then(|| Box::new(ForkNull { tokens }) as Box<dyn SeqBackend>)
            }
        }
        let replica = || {
            let cfg = ServeConfig {
                block_size: 16,
                num_blocks: 1024,
                max_running: 16,
                token_budget: 1024,
                prefill_chunk: 128,
                queue_cap: 256,
                workers: 1,
                enable_prefix_cache: true,
                prefix_cache_blocks: 512,
                ..ServeConfig::default()
            };
            let factory: BackendFactory = Box::new(|_req: &Request| {
                Box::new(ForkNull { tokens: 0 }) as Box<dyn SeqBackend>
            });
            Server::start(cfg, vec![factory])
        };
        let gw = Gateway::new(GatewayConfig::default());
        gw.join(replica());
        gw.join(replica());
        let server = GatewayServer::bind("127.0.0.1:0", gw).expect("bind loopback");
        let addr = server.addr().to_string();
        const REQS: u32 = 64;
        let groups: Vec<Vec<u32>> =
            (0u32..3).map(|g| (g * 1000..g * 1000 + 64).collect()).collect();
        let t0 = std::time::Instant::now();
        for i in 0..REQS {
            let mut prompt = groups[(i % 3) as usize].clone();
            prompt.extend([50_000 + i, 50_100 + i]);
            let body = Json::obj(vec![
                ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t)))),
                ("max_new", Json::num(8.0)),
            ]);
            let mut s = NdjsonStream::post(&addr, "/v1/generate", body.to_string().as_bytes())
                .expect("post /v1/generate");
            assert_eq!(s.status, 200, "generate must stream 200");
            let lines = s.collect_lines().expect("read ndjson stream");
            assert!(lines.last().expect("stream body").contains("\"done\""));
        }
        let wall = t0.elapsed().as_secs_f64();
        let req_s = f64::from(REQS) / wall.max(1e-9);
        let gw = server.gateway();
        for s in gw.statuses() {
            gw.drain(s.id);
        }
        for s in gw.statuses() {
            gw.wait_drained(s.id, 10_000);
        }
        let fleet = gw.fleet_metrics();
        let c = gw.counters();
        assert_eq!(c.generate_failed, 0, "loopback generations must not fail");
        assert!(fleet.prefix_hits > 0, "affinity routing banked no prefix hits");
        println!("\ngateway (2 replicas over loopback HTTP, {REQS} streamed generations):");
        println!(
            "  {req_s:.0} req/s round-trip  prefix hits {} misses {}  saved prefill tokens {}",
            fleet.prefix_hits, fleet.prefix_misses, fleet.saved_prefill_tokens
        );
        record.push((
            "gateway",
            Json::obj(vec![
                ("replicas", Json::num(2.0)),
                ("requests", Json::num(f64::from(REQS))),
                ("wall_s", Json::num(wall)),
                ("requests_per_s", Json::num(req_s)),
                ("prefix_hits", Json::Num(fleet.prefix_hits as f64)),
                ("prefix_misses", Json::Num(fleet.prefix_misses as f64)),
                ("saved_prefill_tokens", Json::Num(fleet.saved_prefill_tokens as f64)),
                ("generate_ok", Json::Num(c.generate_ok as f64)),
                ("generate_failed", Json::Num(c.generate_failed as f64)),
            ]),
        ));
        server.stop();
    }

    // machine-readable record for the scenarios that ran
    std::fs::create_dir_all("results").expect("results dir");
    let record = Json::obj(record);
    std::fs::write("results/coordinator_bench.json", record.to_string())
        .expect("write bench json");
    println!("  wrote results/coordinator_bench.json");
    // repo-root perf-trajectory artifact for this PR (schema shared with
    // benchutil::trajectory / the CI gate) — the bench runs with the
    // package root (rust/) as cwd, so the repo root is one level up
    std::fs::write("../BENCH_10.json", kascade::benchutil::trajectory(10, record).to_string())
        .expect("write trajectory json");
    println!("  wrote ../BENCH_10.json (perf trajectory, PR 10)");

    let _ = Sequence::new(Request::new(vec![]), Session::detached(), Box::new(NullBackend));
}
