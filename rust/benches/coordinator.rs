//! Coordinator hot-path benches: scheduler tick formation, block manager
//! churn, router throughput — the L3 overheads that must stay negligible
//! next to attention work.
//!
//! Run: `cargo bench --bench coordinator`

use kascade::benchutil::{bench, header};
use kascade::config::ServeConfig;
use kascade::coordinator::{BlockManager, Request, Router, SeqBackend, Sequence};
use kascade::server::Engine;

struct NullBackend;

impl SeqBackend for NullBackend {
    fn prefill_chunk(&mut self, _tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        vec![0.0, 1.0]
    }
}

fn main() {
    header();

    // block manager: alloc/extend/free churn
    let mut bm = BlockManager::new(16, 65536);
    let mut next = 0u64;
    bench("block_manager extend+release x1000", 3, 30, || {
        for _ in 0..1000 {
            next += 1;
            bm.extend(next % 512, ((next * 37) % 2000) as usize + 1);
            if next % 3 == 0 {
                bm.release((next + 100) % 512);
            }
        }
    });

    // router
    let mut router = Router::new(8);
    bench("router route x10k (mixed affinity)", 3, 30, || {
        for i in 0..10_000u64 {
            let w = router.route(if i % 2 == 0 { Some(i % 64) } else { None });
            router.release(w);
        }
    });

    // scheduler tick with a large running set (null compute)
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 1 << 16,
        max_running: 256,
        token_budget: 4096,
        prefill_chunk: 512,
        queue_cap: 4096,
        workers: 1,
    };
    let mut engine = Engine::new(cfg, Box::new(|_req: &Request| Box::new(NullBackend) as Box<dyn SeqBackend>));
    for id in 0..256u64 {
        engine.submit(Request {
            id,
            prompt: vec![0; 512],
            max_new: 1_000_000, // keep decoding forever
            stop_token: None,
        });
    }
    // warm into decode phase
    for _ in 0..8 {
        engine.tick();
    }
    bench("scheduler tick (256 running decodes)", 3, 100, || {
        engine.tick();
    });
    println!(
        "\nper-sequence scheduling overhead: see mean/256 — target: <1us/seq (paper's L3 must not bottleneck)"
    );
    let _ = Sequence::new(
        Request { id: 0, prompt: vec![], max_new: 0, stop_token: None },
        Box::new(NullBackend),
    );
}
