//! Table 3 bench: decode + prefill attention time, dense vs the Kascade
//! layer mix, across context lengths and Top-k fractions.  Also reports
//! the paper-config weighting (32 layers / 5 anchors) alongside this
//! model's 16/5 — and, since the tile-major rework, the kernel-level
//! speedup of the tile-major/arena kernels over the retained seed
//! row-at-a-time kernels (`attention::reference`), per storage mode
//! (f32 / f16 / int8 / int4), plus the simd-vs-scalar matrix: the same
//! tile-major kernels at the detected `simd::SimdLevel` against a
//! forced-scalar run of the identical code path (docs/perf.md § SIMD).
//!
//! Run: `cargo bench --bench table3_kernels` (KASCADE_BENCH_FULL=1 for the
//! full context sweep)

use kascade::attention::{self, reference, AttnScratch, CostTracker, IndexSet, KvCache};
use kascade::benchutil::bench;
use kascade::config::{KvDtype, TopKRule};
use kascade::tensor::Rng;

fn fill_cache(n_kv: usize, d: usize, len: usize, dtype: KvDtype, rng: &mut Rng) -> KvCache {
    let mut cache = KvCache::with_opts(n_kv, d, len, 16, dtype);
    let mut k = vec![0.0f32; n_kv * d];
    let mut v = vec![0.0f32; n_kv * d];
    for _ in 0..len {
        rng.fill_normal(&mut k, 0.5);
        rng.fill_normal(&mut v, 1.0);
        cache.push(&k, &v);
    }
    cache
}

fn main() {
    let full = std::env::var("KASCADE_BENCH_FULL").is_ok();
    let (n_kv, g, d) = (4usize, 2usize, 32usize);
    let mut rng = Rng::new(9);
    let ctxs: &[usize] = if full { &[8192, 16384, 32768, 65536, 131072] } else { &[8192, 32768] };
    let fracs: &[f32] = if full { &[0.05, 0.10, 0.20, 0.30] } else { &[0.10, 0.20] };

    println!("# Table 3 kernel bench (decode attention, per step)\n");
    println!("| ctx | k% | dense us | anchor us | reuse us | speedup L16/A5 | speedup L32/A5 |");
    println!("|---|---|---|---|---|---|---|");
    let mut scratch = AttnScratch::new();
    for &len in ctxs {
        let cache = fill_cache(n_kv, d, len, KvDtype::F32, &mut rng);
        let mut q = vec![0.0f32; n_kv * g * d];
        rng.fill_normal(&mut q, 1.0);
        let mut out = vec![0.0f32; n_kv * g * d];
        let samples = (4_000_000 / len).clamp(3, 40);

        let mut cost = CostTracker::default();
        let dense = bench(&format!("dense ctx={len}"), 1, samples, || {
            attention::decode_dense(&q, &cache, g, &mut out, &mut scratch.planes, &mut cost);
        });
        for &f in fracs {
            let k = TopKRule::new(f, 128).k(len);
            let anchor = bench(&format!("anchor ctx={len} k={k}"), 1, samples, || {
                attention::decode_pooled_scores(&q, &cache, g, &mut scratch.planes, &mut cost);
                attention::select_topk(&mut scratch, k, &mut cost);
                let AttnScratch { sel, planes } = &mut scratch;
                attention::decode_sparse(&q, &cache, g, sel, &mut out, planes, &mut cost);
            });
            let fixed = IndexSet::from_nested(
                &(0..n_kv)
                    .map(|h| (0..k as u32).map(|i| (i * 7 + h as u32) % len as u32).collect())
                    .collect::<Vec<Vec<u32>>>(),
            );
            let reuse = bench(&format!("reuse ctx={len} k={k}"), 1, samples, || {
                let planes = &mut scratch.planes;
                attention::decode_sparse(&q, &cache, g, &fixed, &mut out, planes, &mut cost);
            });
            let mix = |l: f64, a: f64| -> f64 {
                let anchor0 = dense.mean_us + (anchor.mean_us - reuse.mean_us);
                (anchor0 + (a - 1.0) * anchor.mean_us + (l - a) * reuse.mean_us) / l
            };
            println!(
                "| {len} | {:.0}% | {:.0} | {:.0} | {:.0} | {:.2} | {:.2} |",
                f * 100.0,
                dense.mean_us,
                anchor.mean_us,
                reuse.mean_us,
                dense.mean_us / mix(16.0, 5.0),
                dense.mean_us / mix(32.0, 5.0),
            );
        }
    }

    // ---- tile-major vs seed row-at-a-time kernels -----------------------
    // The perf claim of the tile-major rework, measured at kernel level:
    // same inputs, same outputs (bitwise — unit-tested), storage-mode
    // dispatch and tile params hoisted out of the inner loops.
    let tm_ctxs: &[usize] = if full { &[8192, 32768, 131072] } else { &[8192, 32768] };
    println!("\n# Tile-major vs seed (row-at-a-time) kernels\n");
    println!("| ctx | dtype | op | seed us | tile us | speedup |");
    println!("|---|---|---|---|---|---|");
    for &len in tm_ctxs {
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8, KvDtype::Int4] {
            let cache = fill_cache(n_kv, d, len, dtype, &mut rng);
            let mut q = vec![0.0f32; n_kv * g * d];
            rng.fill_normal(&mut q, 1.0);
            let mut out = vec![0.0f32; n_kv * g * d];
            let samples = (4_000_000 / len).clamp(3, 30);
            let mut cost = CostTracker::default();
            let k = TopKRule::new(0.10, 128).k(len);
            let idx_nested: Vec<Vec<u32>> = (0..n_kv)
                .map(|h| (0..k as u32).map(|i| (i * 7 + h as u32) % len as u32).collect())
                .collect();
            let sel = IndexSet::from_nested(&idx_nested);

            let seed_dense = bench(&format!("seed dense {}/{len}", dtype.label()), 1, samples, || {
                reference::decode_dense(&q, &cache, g, &mut out, &mut cost);
            });
            let tile_dense = bench(&format!("tile dense {}/{len}", dtype.label()), 1, samples, || {
                attention::decode_dense(&q, &cache, g, &mut out, &mut scratch.planes, &mut cost);
            });
            let seed_pool = bench(&format!("seed pooled {}/{len}", dtype.label()), 1, samples, || {
                let _ = reference::decode_pooled_scores(&q, &cache, g, &mut cost);
            });
            let tile_pool = bench(&format!("tile pooled {}/{len}", dtype.label()), 1, samples, || {
                attention::decode_pooled_scores(&q, &cache, g, &mut scratch.planes, &mut cost);
            });
            let name = format!("seed sparse {}/{len}", dtype.label());
            let seed_sparse = bench(&name, 1, samples, || {
                reference::decode_sparse(&q, &cache, g, &idx_nested, &mut out, &mut cost);
            });
            let name = format!("tile sparse {}/{len}", dtype.label());
            let tile_sparse = bench(&name, 1, samples, || {
                let planes = &mut scratch.planes;
                attention::decode_sparse(&q, &cache, g, &sel, &mut out, planes, &mut cost);
            });
            for (op, s, t) in [
                ("dense", &seed_dense, &tile_dense),
                ("pooled", &seed_pool, &tile_pool),
                ("sparse", &seed_sparse, &tile_sparse),
            ] {
                println!(
                    "| {len} | {} | {op} | {:.0} | {:.0} | {:.2}x |",
                    dtype.label(),
                    s.mean_us,
                    t.mean_us,
                    s.mean_us / t.mean_us.max(1e-9)
                );
            }
        }
    }

    // ---- simd vs scalar dispatch ----------------------------------------
    // Same tile-major kernels, same cache contents: once at the level
    // `simd::detect` resolved for this host and once forced to the
    // scalar reference via `KvCache::set_simd_level`.  The f32 rows and
    // both integer-code rows are bitwise-identical across levels (the
    // lane structure pins the accumulation order — unit-tested in
    // `simd::tests`), so the table isolates pure dispatch upside.
    let detected = kascade::simd::detect();
    let sv_len = if full { 32768 } else { 8192 };
    println!("\n# SIMD vs scalar tile kernels (level {}, ctx {sv_len})\n", detected.label());
    println!("| dtype | op | scalar us | {} us | speedup |", detected.label());
    println!("|---|---|---|---|---|");
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8, KvDtype::Int4] {
        let mut cache = fill_cache(n_kv, d, sv_len, dtype, &mut rng);
        let mut q = vec![0.0f32; n_kv * g * d];
        rng.fill_normal(&mut q, 1.0);
        let mut out = vec![0.0f32; n_kv * g * d];
        let samples = (4_000_000 / sv_len).clamp(3, 30);
        let mut cost = CostTracker::default();
        let k = TopKRule::new(0.10, 128).k(sv_len);
        let sel = IndexSet::from_nested(
            &(0..n_kv)
                .map(|h| (0..k as u32).map(|i| (i * 7 + h as u32) % sv_len as u32).collect())
                .collect::<Vec<Vec<u32>>>(),
        );
        let mut cells: Vec<(&str, f64, f64)> = Vec::new();
        for level in [kascade::simd::SimdLevel::Scalar, detected] {
            cache.set_simd_level(level);
            let tag = level.label();
            let dense = bench(&format!("{tag} dense {}/{sv_len}", dtype.label()), 1, samples, || {
                attention::decode_dense(&q, &cache, g, &mut out, &mut scratch.planes, &mut cost);
            });
            let pool = bench(&format!("{tag} pooled {}/{sv_len}", dtype.label()), 1, samples, || {
                attention::decode_pooled_scores(&q, &cache, g, &mut scratch.planes, &mut cost);
            });
            let sparse = bench(&format!("{tag} sparse {}/{sv_len}", dtype.label()), 1, samples, || {
                let planes = &mut scratch.planes;
                attention::decode_sparse(&q, &cache, g, &sel, &mut out, planes, &mut cost);
            });
            if cells.is_empty() {
                cells.push(("dense", dense.mean_us, 0.0));
                cells.push(("pooled", pool.mean_us, 0.0));
                cells.push(("sparse", sparse.mean_us, 0.0));
            } else {
                cells[0].2 = dense.mean_us;
                cells[1].2 = pool.mean_us;
                cells[2].2 = sparse.mean_us;
            }
        }
        for (op, scalar_us, simd_us) in &cells {
            println!(
                "| {} | {op} | {scalar_us:.0} | {simd_us:.0} | {:.2}x |",
                dtype.label(),
                scalar_us / simd_us.max(1e-9)
            );
        }
    }
}
