//! Fig 8 bench: time split of the anchor-layer multi-pass pipeline
//! (pass 1 scores / pass 2 pooling / pass 3 top-k / pass 4 sparse attend)
//! for decode and prefill at long context.
//!
//! Run: `cargo bench --bench fig8_pass_split`

use kascade::attention::{self, AttnScratch, CostTracker, KvCache};
use kascade::benchutil::bench;
use kascade::config::TopKRule;
use kascade::tensor::Rng;

fn main() {
    let full = std::env::var("KASCADE_BENCH_FULL").is_ok();
    let (n_kv, g, d) = (4usize, 2usize, 32usize);
    let len: usize = if full { 131072 } else { 16384 };
    let k = TopKRule::default().k(len);
    let mut rng = Rng::new(4);
    let mut cache = KvCache::new(n_kv, d, len);
    {
        let mut kb = vec![0.0f32; n_kv * d];
        let mut vb = vec![0.0f32; n_kv * d];
        for _ in 0..len {
            rng.fill_normal(&mut kb, 0.5);
            rng.fill_normal(&mut vb, 1.0);
            cache.push(&kb, &vb);
        }
    }
    let samples = if full { 3 } else { 10 };
    let mut scratch = AttnScratch::new();

    println!("# Fig 8 — anchor pass split at ctx {len}, k {k}\n");
    println!("## decode");
    let mut q = vec![0.0f32; n_kv * g * d];
    rng.fill_normal(&mut q, 1.0);
    let mut out = vec![0.0f32; n_kv * g * d];
    let mut cost = CostTracker::default();
    // pass 1+2 are fused in the native engine (scores+softmax+pool);
    // measure pooled-scores, top-k, sparse-attend separately.
    let p12 = bench("decode pass1+2 (scores+pool)", 1, samples, || {
        attention::decode_pooled_scores(&q, &cache, g, &mut scratch.planes, &mut cost);
    });
    attention::decode_pooled_scores(&q, &cache, g, &mut scratch.planes, &mut cost);
    let p3 = bench("decode pass3 (top-k)", 1, samples, || {
        attention::select_topk(&mut scratch, k, &mut cost);
    });
    attention::select_topk(&mut scratch, k, &mut cost);
    let p4 = bench("decode pass4 (sparse attend)", 1, samples, || {
        let AttnScratch { sel, planes } = &mut scratch;
        attention::decode_sparse(&q, &cache, g, sel, &mut out, planes, &mut cost);
    });
    let total = p12.mean_us + p3.mean_us + p4.mean_us;
    println!(
        "\nsplit: pass1+2 {:.0}%  pass3 {:.0}%  pass4 {:.0}%  (total {:.0} us)\n",
        100.0 * p12.mean_us / total,
        100.0 * p3.mean_us / total,
        100.0 * p4.mean_us / total,
        total
    );

    println!("## prefill (one 128-query tile at the frontier)");
    let tile = 128;
    let start = len - tile;
    let mut qs = vec![0.0f32; tile * n_kv * g * d];
    rng.fill_normal(&mut qs, 1.0);
    let mut pout = vec![0.0f32; tile * n_kv * g * d];
    let p12 = bench("prefill pass1+2 (stats+pool)", 1, samples, || {
        attention::prefill_pooled_scores(&qs, start, &cache, g, &mut scratch.planes, &mut cost);
    });
    attention::prefill_pooled_scores(&qs, start, &cache, g, &mut scratch.planes, &mut cost);
    let p3 = bench("prefill pass3 (top-k)", 1, samples, || {
        attention::select_topk(&mut scratch, k, &mut cost);
    });
    attention::select_topk(&mut scratch, k, &mut cost);
    let p4 = bench("prefill pass4 (sparse attend)", 1, samples, || {
        let AttnScratch { sel, planes } = &mut scratch;
        attention::prefill_sparse_tile(&qs, start, &cache, g, sel, &mut pout, planes, &mut cost);
    });
    let total = p12.mean_us + p3.mean_us + p4.mean_us;
    println!(
        "\nsplit: pass1+2 {:.0}%  pass3 {:.0}%  pass4 {:.0}%  (total {:.0} us)",
        100.0 * p12.mean_us / total,
        100.0 * p3.mean_us / total,
        100.0 * p4.mean_us / total,
        total
    );
    println!("(paper Fig 8: prefill is dominated by the pass-2 recompute — same shape here)");
}
