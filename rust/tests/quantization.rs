//! Compressed paged-KV integration suite: round-trip error bounds
//! (property-tested, int8 and int4), f32-vs-int8 Top-k tile selection
//! identity on synthetic score landscapes with margin, CoW-fork
//! preservation of compressed tiles across every storage mode (no
//! re-conversion), and end-to-end output divergence of the f16 / int8 /
//! int4 streams against f32 serving.

use kascade::attention::{self, CostTracker, KvCache};
use kascade::config::{KvDtype, ServeConfig, TopKRule};
use kascade::coordinator::{NativeBackend, Request, SeqBackend};
use kascade::kascade::KascadePlan;
use kascade::model::SynthSpec;
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::server::Engine;
use kascade::sparse::{DensePolicy, KascadePolicy};
use kascade::tensor::{dequantize_q4, dequantize_q8, quantize_q4, quantize_q8};
use kascade::workload::WorkloadGen;
use std::sync::Arc;

/// Round-trip error of affine int8 quantization is bounded by half a
/// quantization step, `(max - min) / 508`, for arbitrary tiles.
#[test]
fn prop_quantize_round_trip_error_bound() {
    check("quantize round trip", 40, |rng| {
        let n = 1 + rng.below(512);
        let spread = 0.01 + rng.uniform() * 20.0;
        let shift = rng.normal() * 5.0;
        let src: Vec<f32> = (0..n).map(|_| rng.normal() * spread + shift).collect();
        let mut q = vec![0i8; n];
        let (s, z) = quantize_q8(&src, &mut q);
        let mut back = vec![0.0f32; n];
        dequantize_q8(&q, s, z, &mut back);
        let lo = src.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = src.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let bound = (hi - lo) / 508.0 + (hi - lo).abs().max(1.0) * 1e-6;
        for (i, (a, b)) in src.iter().zip(&back).enumerate() {
            prop_assert!(
                (a - b).abs() <= bound,
                "elem {i}: {a} vs {b} exceeds bound {bound}"
            );
        }
        Ok(())
    });
}

/// Round-trip error of packed affine int4 quantization is bounded by
/// half a step, `(max - min) / 28` (15 codes minus the reserved code
/// give 14 steps over the range), for arbitrary even-length tiles.
#[test]
fn prop_quantize_q4_round_trip_error_bound() {
    check("quantize q4 round trip", 40, |rng| {
        let n = 2 * (1 + rng.below(256));
        let spread = 0.01 + rng.uniform() * 20.0;
        let shift = rng.normal() * 5.0;
        let src: Vec<f32> = (0..n).map(|_| rng.normal() * spread + shift).collect();
        let mut q = vec![0u8; n / 2];
        let (s, z) = quantize_q4(&src, &mut q);
        let mut back = vec![0.0f32; n];
        dequantize_q4(&q, s, z, &mut back);
        let lo = src.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = src.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let bound = (hi - lo) / 28.0 + (hi - lo).abs().max(1.0) * 1e-6;
        for (i, (a, b)) in src.iter().zip(&back).enumerate() {
            prop_assert!(
                (a - b).abs() <= bound,
                "elem {i}: {a} vs {b} exceeds bound {bound}"
            );
        }
        Ok(())
    });
}

/// Quantizing a cache must not change which tiles Top-k selects when the
/// score landscape has margin: plant exactly `k` strongly aligned keys
/// among low noise and require bitwise-identical selections (as sets)
/// from f32 and int8 caches, across random layouts.
#[test]
fn prop_topk_selection_identical_f32_vs_int8() {
    check("topk selection f32 vs int8", 15, |rng| {
        let (n_kv, g, d) = (2usize, 2usize, 16usize);
        let len = 192 + rng.below(4) * 64; // 192..384
        let k = TopKRule::new(0.1, 16).k(len);
        let mut q = vec![0.0; n_kv * g * d];
        rng.fill_normal(&mut q, 1.0);
        // k distinct planted positions
        let mut all: Vec<usize> = (0..len).collect();
        rng.shuffle(&mut all);
        let mut strong = all[..k].to_vec();
        strong.sort_unstable();
        let mut cf = KvCache::new(n_kv, d, len);
        let mut cq = KvCache::with_opts(n_kv, d, len, 16, KvDtype::Int8);
        for p in 0..len {
            let mut kr = vec![0.0; n_kv * d];
            let mut vr = vec![0.0; n_kv * d];
            rng.fill_normal(&mut kr, 0.05);
            rng.fill_normal(&mut vr, 1.0);
            if strong.binary_search(&p).is_ok() {
                for h in 0..n_kv {
                    for i in 0..d {
                        kr[h * d + i] = q[h * g * d + i] * 2.0;
                    }
                }
            }
            cf.push(&kr, &vr);
            cq.push(&kr, &vr);
        }
        let mut cost_f = CostTracker::default();
        let mut cost_q = CostTracker::default();
        let mut scr_f = attention::AttnScratch::new();
        let mut scr_q = attention::AttnScratch::new();
        attention::decode_pooled_scores(&q, &cf, g, &mut scr_f.planes, &mut cost_f);
        attention::decode_pooled_scores(&q, &cq, g, &mut scr_q.planes, &mut cost_q);
        prop_assert!(
            cost_q.dequant_rows == 0,
            "pooled scoring over int8 must be fused (dequant_rows {})",
            cost_q.dequant_rows
        );
        attention::select_topk(&mut scr_f, k, &mut cost_f);
        attention::select_topk(&mut scr_q, k, &mut cost_q);
        for h in 0..n_kv {
            let mut a = scr_f.sel.head(h).to_vec();
            let mut b = scr_q.sel.head(h).to_vec();
            a.sort_unstable();
            b.sort_unstable();
            prop_assert!(a == b, "head {h}: f32 {a:?} != int8 {b:?} (len {len}, k {k})");
            let want: Vec<u32> = strong.iter().map(|&p| p as u32).collect();
            prop_assert!(a == want, "head {h}: planted set not selected");
        }
        Ok(())
    });
}

/// A prefix-cache fork of an int8 backend shares the completed quantized
/// tiles byte-for-byte — the fork must NOT re-quantize them (block
/// boundaries equal tile boundaries, so a block-aligned fork point never
/// splits a tile).
#[test]
fn cow_fork_preserves_quantized_tiles_bitwise() {
    let mut spec = SynthSpec::eval_base(0xAB);
    spec.cfg.n_layers = 4;
    spec.block_starts = vec![1];
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0xF00);
    let prompt = gen.dev_prompt(96); // 6 full 16-token tiles
    let mut parent =
        NativeBackend::with_dtype(model.clone(), 256, Box::new(DensePolicy), KvDtype::Int8);
    parent.prefill_chunk(&prompt[..prompt.len() - 1], false);
    parent.prefill_chunk(&prompt[prompt.len() - 1..], true);
    let boundary = 64; // block- and tile-aligned
    assert!(parent.fork_prefix(boundary).is_some(), "int8 backend must support forking");
    // fork_prefix is clone + tile-aligned truncate: reproduce it on the
    // state directly so the quantized tiles are comparable byte-for-byte
    let mut st2 = parent.st.clone();
    for c in &mut st2.caches {
        c.truncate(boundary);
    }
    for layer in 0..model.cfg.n_layers {
        let a = &parent.st.caches[layer];
        let b = &st2.caches[layer];
        for h in 0..model.cfg.n_kv_heads {
            for pos in 0..boundary {
                let (ra, sa, za) = a.quantized_key_row(h, pos).unwrap();
                let (rb, sb, zb) = b.quantized_key_row(h, pos).unwrap();
                assert_eq!(ra, rb, "layer {layer} head {h} pos {pos}: int8 bytes re-quantized");
                assert_eq!(sa.to_bits(), sb.to_bits());
                assert_eq!(za.to_bits(), zb.to_bits());
            }
        }
    }
}

/// The same CoW-fork byte-stability for the other compressed modes: a
/// block-aligned fork shares f16 planes and packed int4 tiles
/// byte-for-byte — no re-conversion, no re-quantization.
#[test]
fn cow_fork_preserves_f16_and_int4_tiles_bitwise() {
    let mut spec = SynthSpec::eval_base(0xAB);
    spec.cfg.n_layers = 4;
    spec.block_starts = vec![1];
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0xF01);
    let prompt = gen.dev_prompt(96); // 6 full 16-token tiles
    let boundary = 64; // block- and tile-aligned
    for dtype in [KvDtype::F16, KvDtype::Int4] {
        let mut parent =
            NativeBackend::with_dtype(model.clone(), 256, Box::new(DensePolicy), dtype);
        parent.prefill_chunk(&prompt[..prompt.len() - 1], false);
        parent.prefill_chunk(&prompt[prompt.len() - 1..], true);
        assert!(
            parent.fork_prefix(boundary).is_some(),
            "{} backend must support forking",
            dtype.label()
        );
        let mut st2 = parent.st.clone();
        for c in &mut st2.caches {
            c.truncate(boundary);
        }
        for layer in 0..model.cfg.n_layers {
            let a = &parent.st.caches[layer];
            let b = &st2.caches[layer];
            for h in 0..model.cfg.n_kv_heads {
                for pos in 0..boundary {
                    match dtype {
                        KvDtype::F16 => {
                            let ra = a.f16_key_row(h, pos).unwrap();
                            let rb = b.f16_key_row(h, pos).unwrap();
                            assert_eq!(
                                ra, rb,
                                "layer {layer} head {h} pos {pos}: f16 bits re-converted"
                            );
                        }
                        KvDtype::Int4 => {
                            let (ra, sa, za) = a.packed_key_row(h, pos).unwrap();
                            let (rb, sb, zb) = b.packed_key_row(h, pos).unwrap();
                            assert_eq!(
                                ra, rb,
                                "layer {layer} head {h} pos {pos}: int4 codes re-quantized"
                            );
                            assert_eq!(sa.to_bits(), sb.to_bits());
                            assert_eq!(za.to_bits(), zb.to_bits());
                        }
                        _ => unreachable!(),
                    }
                }
            }
        }
    }
}

/// End-to-end: f16 and int4 serving through the engine shrink peak KV
/// bytes and stay within their per-mode divergence bounds of the f32
/// stream (f16 is a precision change — tiny drift, zero dequants; int4
/// is the capacity-stretch mode — looser bound, dequantized attends).
#[test]
fn f16_and_int4_engine_bounded_divergence_and_smaller_kv() {
    let mut spec = SynthSpec::eval_base(0xC4);
    spec.cfg.n_layers = 6;
    spec.block_starts = vec![1, 3];
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0xBEF);
    let prompts: Vec<Vec<u32>> = (0..2).map(|_| gen.dev_prompt(96)).collect();
    let run = |dtype: KvDtype| {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 1024,
            max_running: 4,
            token_budget: 512,
            prefill_chunk: 128,
            queue_cap: 16,
            workers: 1,
            kv_dtype: dtype,
            ..ServeConfig::default()
        };
        let model = model.clone();
        let mut engine = Engine::new(
            cfg,
            Box::new(move |_req: &Request| {
                let plan = KascadePlan::from_anchors(6, 4, vec![0, 3], TopKRule::new(0.25, 16));
                Box::new(NativeBackend::with_dtype(
                    model.clone(),
                    256,
                    Box::new(KascadePolicy::new(plan)),
                    dtype,
                )) as Box<dyn SeqBackend>
            }),
        );
        let mut handles = Vec::new();
        for p in &prompts {
            handles.push(
                engine
                    .submit(Request::new(p.clone()).max_new(12))
                    .expect("admission"),
            );
        }
        let mut done = engine.run_to_completion(&mut handles);
        done.sort_by_key(|c| c.id);
        let toks: Vec<Vec<u32>> = done.into_iter().map(|c| c.tokens).collect();
        (toks, engine.metrics.peak_kv_bytes, engine.metrics.dequant_rows)
    };
    let (tf, bytes_f, _) = run(KvDtype::F32);
    let (_, bytes_h, deq_h) = run(KvDtype::F16);
    let (_, bytes_p, deq_p) = run(KvDtype::Int4);
    assert_eq!(deq_h, 0, "f16 reads are conversions, not dequants");
    assert!(deq_p > 0, "int4 serving must report dequantized rows");
    let ratio_h = bytes_f as f64 / bytes_h as f64;
    let ratio_p = bytes_f as f64 / bytes_p as f64;
    assert!(ratio_h >= 1.5, "f16 peak KV bytes ratio {ratio_h:.2} below 1.5x");
    assert!(ratio_p >= 2.5, "int4 peak KV bytes ratio {ratio_p:.2} below 2.5x");
    assert!(bytes_p < bytes_h, "int4 must sit below f16 peak bytes");
    // teacher-forced divergence on the f32 streams, per-mode bounds
    for (dtype, bound) in [(KvDtype::F16, 0.05f64), (KvDtype::Int4, 1.0f64)] {
        let mut max_rel = 0.0f64;
        for (p, stream) in prompts.iter().zip(&tf) {
            let mut st_f = model.new_state_with_dtype(256, KvDtype::F32);
            let mut st_q = model.new_state_with_dtype(256, dtype);
            let mut pol_f = DensePolicy;
            let mut pol_q = DensePolicy;
            let (lf, _) = model.prefill(p, &mut st_f, &mut pol_f, None);
            let (lq, _) = model.prefill(p, &mut st_q, &mut pol_q, None);
            max_rel = max_rel.max(rel_l2(&lf, &lq));
            for &tok in stream {
                let lf = model.decode_step(tok, &mut st_f, &mut pol_f);
                let lq = model.decode_step(tok, &mut st_q, &mut pol_q);
                max_rel = max_rel.max(rel_l2(&lf, &lq));
            }
        }
        assert!(
            max_rel <= bound,
            "{} per-token logit divergence {max_rel:.4} exceeds bound {bound}",
            dtype.label()
        );
    }
}

/// End-to-end: int8 serving through the engine must stay within a small
/// per-token divergence of the f32 stream, at a large KV-byte reduction.
#[test]
fn int8_engine_bounded_divergence_and_smaller_kv() {
    let mut spec = SynthSpec::eval_base(0xC4);
    spec.cfg.n_layers = 6;
    spec.block_starts = vec![1, 3];
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0xBEE);
    let prompts: Vec<Vec<u32>> = (0..4).map(|_| gen.dev_prompt(96)).collect();
    let run = |dtype: KvDtype| {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 1024,
            max_running: 4,
            token_budget: 512,
            prefill_chunk: 128,
            queue_cap: 16,
            workers: 1,
            kv_dtype: dtype,
            ..ServeConfig::default()
        };
        let model = model.clone();
        let mut engine = Engine::new(
            cfg,
            Box::new(move |_req: &Request| {
                let plan = KascadePlan::from_anchors(6, 4, vec![0, 3], TopKRule::new(0.25, 16));
                Box::new(NativeBackend::with_dtype(
                    model.clone(),
                    256,
                    Box::new(KascadePolicy::new(plan)),
                    dtype,
                )) as Box<dyn SeqBackend>
            }),
        );
        let mut handles = Vec::new();
        for p in &prompts {
            handles.push(
                engine
                    .submit(Request::new(p.clone()).max_new(16))
                    .expect("admission"),
            );
        }
        let mut done = engine.run_to_completion(&mut handles);
        done.sort_by_key(|c| c.id);
        let toks: Vec<Vec<u32>> = done.into_iter().map(|c| c.tokens).collect();
        (toks, engine.metrics.peak_kv_bytes, engine.metrics.dequant_rows)
    };
    let (tf, bytes_f, deq_f) = run(KvDtype::F32);
    let (_tq, bytes_q, deq_q) = run(KvDtype::Int8);
    assert_eq!(deq_f, 0, "f32 serving never dequantizes");
    assert!(deq_q > 0, "int8 serving must report dequantized rows");
    let ratio = bytes_f as f64 / bytes_q as f64;
    assert!(ratio >= 1.8, "peak KV bytes ratio {ratio:.2} below 1.8x");
    // per-token divergence bound, teacher-forced on the f32 stream so a
    // single low-margin argmax flip cannot cascade: feed the f32 run's
    // tokens to both precisions and bound the relative logit error
    let mut max_rel = 0.0f64;
    for (p, stream) in prompts.iter().zip(&tf) {
        let mut st_f = model.new_state_with_dtype(256, KvDtype::F32);
        let mut st_q = model.new_state_with_dtype(256, KvDtype::Int8);
        let mut pol_f = DensePolicy;
        let mut pol_q = DensePolicy;
        let (lf, _) = model.prefill(p, &mut st_f, &mut pol_f, None);
        let (lq, _) = model.prefill(p, &mut st_q, &mut pol_q, None);
        max_rel = max_rel.max(rel_l2(&lf, &lq));
        for &tok in stream {
            let lf = model.decode_step(tok, &mut st_f, &mut pol_f);
            let lq = model.decode_step(tok, &mut st_q, &mut pol_q);
            max_rel = max_rel.max(rel_l2(&lf, &lq));
        }
    }
    assert!(max_rel <= 0.15, "per-token logit divergence {max_rel:.4} exceeds bound 0.15");
}

/// Relative L2 distance between two logit vectors.
fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (*x as f64).powi(2);
    }
    (num / den.max(1e-12)).sqrt()
}

/// `SparsePolicy::fork_fresh` + int8: a resumed sequence rebuilds its own
/// Top-k state, but the adopted quantized KV is shared — its scoring
/// must match the parent's bit-for-bit on the shared prefix.
#[test]
fn forked_policy_scores_shared_int8_prefix_identically() {
    let mut spec = SynthSpec::eval_base(0xD5);
    spec.cfg.n_layers = 4;
    spec.block_starts = vec![1];
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0xA11);
    let prompt = gen.dev_prompt(64);
    let plan = KascadePlan::from_anchors(4, 4, vec![0, 2], TopKRule::new(0.25, 8));
    let mut parent = NativeBackend::with_dtype(
        model.clone(),
        256,
        Box::new(KascadePolicy::new(plan)),
        KvDtype::Int8,
    );
    parent.prefill_chunk(&prompt, true);
    let mut child = parent.fork_prefix(48).expect("fork at block boundary");
    // both decode the same next token from the shared 48-token prefix:
    // the child's caches must contain the identical quantized tiles, so
    // after the parent is truncated to the same point their logits match
    let mut parent_trunc = parent.fork_prefix(48).expect("second fork");
    let la = parent_trunc.decode(7);
    let lb = child.decode(7);
    for (a, b) in la.iter().zip(&lb) {
        assert_eq!(a.to_bits(), b.to_bits(), "shared int8 prefix scored differently");
    }
}
