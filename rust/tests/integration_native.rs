//! Cross-module integration: SynthLM + calibration + policies + serving
//! engine, asserting the paper's *qualitative* results end-to-end:
//! Kascade matches dense accuracy at 10% Top-k while StreamingLLM fails
//! out-of-window retrieval (Table 2's shape).

use kascade::config::{ServeConfig, TopKRule};
use kascade::coordinator::{NativeBackend, Request};
use kascade::kascade::{calibrate, CalibrateOptions, KascadePlan};
use kascade::model::{Model, SynthSpec};
use kascade::server::Engine;
use kascade::sparse::*;
use kascade::tensor::argmax;
use kascade::workload::{grade, Category, WorkloadGen};
use std::sync::Arc;

fn setup() -> (SynthSpec, Model, KascadePlan) {
    let mut spec = SynthSpec::eval_base(123);
    spec.cfg.n_layers = 8;
    spec.block_starts = vec![1, 4];
    let model = spec.build();
    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let prompts: Vec<Vec<u32>> = (0..2).map(|_| dev.dev_prompt(768)).collect();
    let cal = calibrate(
        &model,
        &prompts,
        &CalibrateOptions { anchors: 3, topk: TopKRule::new(0.10, 64), ..Default::default() },
    );
    (spec, model, cal.plan)
}

fn run_policy(model: &Model, task: &kascade::workload::Task, mut policy: Box<dyn SparsePolicy>) -> Vec<u32> {
    let mut st = model.new_state(task.prompt.len() + task.max_new + 8);
    let (logits, _) = model.prefill(&task.prompt, &mut st, policy.as_mut(), None);
    let stop = *task.expect.last().unwrap();
    model.greedy_decode(&logits, &mut st, policy.as_mut(), task.max_new, |t| t == stop)
}

#[test]
fn kascade_matches_dense_accuracy_streaming_fails() {
    let (spec, model, plan) = setup();
    let mut gen = WorkloadGen::new(&spec, 0x17E5);
    let mut dense_ok = 0;
    let mut kascade_ok = 0;
    let mut stream_ok = 0;
    let n = 6;
    for _ in 0..n {
        let t = gen.longbench(Category::Sqa, 1024);
        if grade(&t, &run_policy(&model, &t, Box::new(DensePolicy))) {
            dense_ok += 1;
        }
        if grade(&t, &run_policy(&model, &t, Box::new(KascadePolicy::new(plan.clone())))) {
            kascade_ok += 1;
        }
        if grade(&t, &run_policy(&model, &t, Box::new(StreamingLlmPolicy::paper_default()))) {
            stream_ok += 1;
        }
    }
    assert_eq!(dense_ok, n, "dense must be exact on SynthLM");
    assert!(kascade_ok >= n - 1, "kascade {kascade_ok}/{n} should match dense");
    assert!(
        stream_ok <= n / 2,
        "streaming ({stream_ok}/{n}) must fail needles outside its window"
    );
}

#[test]
fn kascade_multihop_chain_follows_to_terminal() {
    let (spec, model, plan) = setup();
    let mut gen = WorkloadGen::new(&spec, 0xA13E);
    let t = gen.aime(1024, 5);
    let out = run_policy(&model, &t, Box::new(KascadePolicy::new(plan)));
    assert!(grade(&t, &out), "chain {:?} vs expected {:?}", out, t.expect);
    assert_eq!(out.len(), t.expect.len(), "no wandering on a clean chain");
}

#[test]
fn oracle_beats_random_sized_subsets() {
    // oracle top-10% matches dense; the same k of *worst* keys fails —
    // the Sec. 3.1 premise that selection quality is what matters
    let (spec, model, _) = setup();
    let mut gen = WorkloadGen::new(&spec, 0x0AC1E);
    let t = gen.longbench(Category::Synthetic, 1024);
    let oracle = run_policy(&model, &t, Box::new(OraclePolicy::new(TopKRule::new(0.10, 32))));
    assert!(grade(&t, &oracle));
    let stream = run_policy(
        &model,
        &t,
        Box::new(StreamingLlmPolicy { window_frac: 0.10, sinks: 4 }),
    );
    // a same-budget fixed window misses the needle (planted interior)
    assert_ne!(oracle, stream);
}

#[test]
fn served_kascade_engine_end_to_end() {
    let (spec, model, plan) = setup();
    let model = Arc::new(model);
    let mut gen = WorkloadGen::new(&spec, 0x5E12E);
    let mut expected = Vec::new();
    let factory: kascade::server::LocalBackendFactory = {
        let model = model.clone();
        let plan = plan.clone();
        Box::new(move |_req| {
            Box::new(NativeBackend::new(
                model.clone(),
                1200,
                Box::new(KascadePolicy::new(plan.clone())),
            ))
        })
    };
    let mut engine = Engine::new(
        ServeConfig {
            num_blocks: 4096,
            token_budget: 1024,
            prefill_chunk: 256,
            ..ServeConfig::default()
        },
        factory,
    );
    let mut handles = Vec::new();
    for _ in 0..4u64 {
        let t = gen.longbench(Category::Fewshot, 900);
        expected.push(t.expect[0]);
        handles.push(
            engine
                .submit(Request::new(t.prompt).max_new(2).stop(t.expect[0]))
                .expect("admission"),
        );
    }
    let done = engine.run_to_completion(&mut handles);
    assert_eq!(done.len(), 4);
    let correct = done
        .iter()
        .filter(|c| c.tokens.first() == Some(&expected[c.id as usize]))
        .count();
    assert!(correct >= 3, "served kascade accuracy {correct}/4");
    engine.sched.blocks.check_invariants().unwrap();
}

#[test]
fn plan_json_roundtrip_through_disk() {
    let (_, _, plan) = setup();
    let dir = std::env::temp_dir().join("kascade_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan.json");
    plan.save(&path).unwrap();
    let loaded = KascadePlan::load(&path).unwrap();
    assert_eq!(loaded.anchors, plan.anchors);
    assert_eq!(loaded.head_map, plan.head_map);
    assert_eq!(loaded.segment_of, plan.segment_of);
}

#[test]
fn sparsity_reduces_decode_work_roughly_by_plan_ratio() {
    let (spec, model, plan) = setup();
    let mut gen = WorkloadGen::new(&spec, 0xC057);
    let t = gen.longbench(Category::Sqa, 1024);
    let run_cost = |mut policy: Box<dyn SparsePolicy>| -> u64 {
        let mut st = model.new_state(t.prompt.len() + 16);
        let (logits, _) = model.prefill(&t.prompt, &mut st, policy.as_mut(), None);
        let before = st.cost.attend_kv_reads;
        let _ = model.greedy_decode(&logits, &mut st, policy.as_mut(), 4, |_| false);
        st.cost.attend_kv_reads - before
    };
    let dense = run_cost(Box::new(DensePolicy));
    let kas = run_cost(Box::new(KascadePolicy::new(plan.clone())));
    let ratio = dense as f64 / kas as f64;
    assert!(
        ratio > 1.5,
        "kascade decode reads should be well below dense (got {ratio:.2}x)"
    );
}

#[test]
fn logit_divergence_kascade_under_all_pooled_under_streaming() {
    // output-fidelity ordering on the query token (Fig 6 / Table 1 shape):
    // needle planted *early*, i.e. outside StreamingLLM's trailing window
    let (spec, model, plan) = setup();
    let lay = spec.vocab_layout();
    let mut prompt = vec![kascade::model::VocabLayout::BOS];
    for f in 0..1020 {
        prompt.push(lay.filler_tok(f * 5 + 2));
    }
    prompt[12] = lay.pair_tok(7, 21); // far outside the 30% window
    prompt.push(kascade::model::VocabLayout::QUERY);
    prompt.push(lay.key_tok(7));
    let t = kascade::workload::Task {
        prompt,
        expect: vec![lay.value_tok(21)],
        max_new: 2,
        hops: 1,
    };
    let logits_of = |mut p: Box<dyn SparsePolicy>| -> Vec<f32> {
        let mut st = model.new_state(t.prompt.len() + 8);
        model.prefill(&t.prompt, &mut st, p.as_mut(), None).0
    };
    let dense = logits_of(Box::new(DensePolicy));
    let kas = logits_of(Box::new(KascadePolicy::new(plan.clone())));
    let stream = logits_of(Box::new(StreamingLlmPolicy::paper_default()));
    let l2 = |a: &[f32], b: &[f32]| -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt()
    };
    let dk = l2(&dense, &kas);
    let ds = l2(&dense, &stream);
    assert!(dk < ds, "kascade divergence {dk:.2} should beat streaming {ds:.2}");
    assert_eq!(argmax(&dense), argmax(&kas));
}
