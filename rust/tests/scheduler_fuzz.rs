//! Scheduler / engine fuzz (seeded, deterministic): random
//! submit/tick/finish/preempt streams with prefix caching enabled,
//! interleaved with random cancellations and deadlines.
//!
//! Properties:
//! * a tick never exceeds `token_budget` and never schedules the same
//!   sequence twice in one batch;
//! * block-manager invariants survive arbitrary interleavings of
//!   admission, cache adoption, preemption, eviction and cancellation
//!   (`Scheduler::remove` at any phase), with zero leaked blocks after
//!   drain and no orphaned engine snapshots;
//! * every preempted-and-not-cancelled sequence is eventually
//!   re-admitted and completes (no livelock);
//! * under heavy preemption + prefix caching, every request completes
//!   with output tokens identical to an unpressured run.

use kascade::config::ServeConfig;
use kascade::coordinator::{
    Completion, Event, FailReason, Request, Scheduler, SeqBackend, SeqPhase, WorkItem,
};
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::server::Engine;
use kascade::tensor::Rng;
use std::collections::{HashMap, HashSet};

#[test]
fn fuzz_scheduler_budget_uniqueness_and_preemption_recovery() {
    check("scheduler fuzz", 15, |rng| {
        let bs = 2 + rng.below(14);
        let c = ServeConfig {
            block_size: bs,
            num_blocks: 12 + rng.below(40),
            max_running: 1 + rng.below(6),
            token_budget: 8 + rng.below(128),
            prefill_chunk: 1 + rng.below(64),
            queue_cap: 1024,
            workers: 1,
            enable_prefix_cache: true,
            prefix_cache_blocks: 8 + rng.below(32),
            batched_decode: true,
            ..ServeConfig::default()
        };
        let budget = c.token_budget;
        let mut s = Scheduler::new(c);
        // (phase, prompt_len, stored_tokens) as the engine would report
        let mut phases: HashMap<u64, (SeqPhase, usize, usize)> = HashMap::new();
        let mut prompts: HashMap<u64, Vec<u32>> = HashMap::new();
        // lifetime response tokens per sequence (engine's emitted_total)
        let mut resp: HashMap<u64, usize> = HashMap::new();
        let mut next = 0u64;
        let mut preempted_ever: HashSet<u64> = HashSet::new();
        let mut readmitted: HashSet<u64> = HashSet::new();

        let one_tick = |s: &mut Scheduler,
                            phases: &mut HashMap<u64, (SeqPhase, usize, usize)>,
                            prompts: &mut HashMap<u64, Vec<u32>>,
                            resp: &mut HashMap<u64, usize>,
                            preempted_ever: &mut HashSet<u64>,
                            readmitted: &mut HashSet<u64>,
                            rng: &mut Rng,
                            drain: bool,
                            step: usize|
         -> Result<usize, String> {
            let batch = {
                let ph = phases.clone();
                s.tick(move |id| ph.get(&id).copied())
            };
            // engine-style: drain eviction invalidations before this
            // tick's registrations
            s.take_invalidated();
            prop_assert!(
                batch.budget_used <= budget,
                "step {step}: budget {} > {budget}",
                batch.budget_used
            );
            let mut seen = HashSet::new();
            for it in &batch.items {
                let id = match it {
                    WorkItem::Prefill { seq, .. } | WorkItem::Decode { seq } => *seq,
                };
                prop_assert!(seen.insert(id), "step {step}: duplicate work for {id}");
            }
            s.blocks.check_invariants().map_err(|e| format!("step {step}: {e}"))?;
            // engine-style cache-hit fast-forward
            for &(id, cached, _hash) in &batch.cache_hits {
                let e = phases.get_mut(&id).ok_or("hit for unknown seq")?;
                prop_assert!(
                    matches!(e.0, SeqPhase::Waiting),
                    "step {step}: cache hit on non-waiting {id}"
                );
                prop_assert!(cached < e.1, "step {step}: cached {cached} >= prompt {}", e.1);
                *e = (SeqPhase::Prefilling { done: cached }, e.1, cached);
                if preempted_ever.contains(&id) {
                    readmitted.insert(id);
                }
            }
            let n = batch.items.len();
            // apply work
            for it in &batch.items {
                match *it {
                    WorkItem::Prefill { seq, tokens } => {
                        let (ph, plen, tot) = phases[&seq];
                        let done = match ph {
                            SeqPhase::Waiting => 0,
                            SeqPhase::Prefilling { done } => done,
                            _ => continue,
                        };
                        if preempted_ever.contains(&seq) {
                            readmitted.insert(seq);
                        }
                        let nd = done + tokens;
                        let nph = if nd >= plen {
                            SeqPhase::Decoding
                        } else {
                            SeqPhase::Prefilling { done: nd }
                        };
                        phases.insert(seq, (nph, plen, tot + tokens));
                        // engine-style registration; resumable models
                        // "the backend produced a snapshot here"
                        let boundary = nd.min(plen - 1) / bs * bs;
                        if boundary > 0 {
                            s.register_prefix(seq, boundary, drain || rng.below(2) == 0);
                        }
                    }
                    WorkItem::Decode { seq } => {
                        let (_, plen, tot) = phases[&seq];
                        let r = resp.entry(seq).or_insert(0);
                        *r += 1;
                        // bounded responses keep recompute-preemption
                        // footprints admissible (mirrors max_new)
                        if *r >= 4 || (!drain && rng.below(6) == 0) {
                            phases.remove(&seq);
                            s.on_finished(seq);
                        } else {
                            phases.insert(seq, (SeqPhase::Decoding, plen, tot + 1));
                        }
                    }
                }
            }
            // recompute-style preemption: emitted folds into the prompt
            for &p in &batch.preempted {
                preempted_ever.insert(p);
                if let Some(e) = phases.get_mut(&p) {
                    let new_len = e.2.max(e.1);
                    let prompt = prompts.get_mut(&p).ok_or("preempt unknown prompt")?;
                    while prompt.len() < new_len {
                        prompt.push(7); // synthetic emitted token
                    }
                    prompt.truncate(new_len.max(e.1));
                    *e = (SeqPhase::Waiting, prompt.len(), 0);
                    s.set_prompt(p, prompt);
                }
            }
            Ok(n)
        };

        let mut cancelled_count = 0u64;
        for step in 0..120 {
            for _ in 0..rng.below(3) {
                next += 1;
                // tiny token alphabet -> organic prefix collisions
                let len = 1 + rng.below(6 * bs);
                let prompt: Vec<u32> = (0..len).map(|_| rng.below(3) as u32).collect();
                s.submit_with_prompt(next, &prompt);
                phases.insert(next, (SeqPhase::Waiting, len, 0));
                prompts.insert(next, prompt);
            }
            // random cancellation at an arbitrary phase (waiting,
            // mid-prefill, decoding, freshly preempted): the typed
            // teardown path must keep the pool clean at any interleaving
            if rng.below(5) == 0 && !phases.is_empty() {
                let mut live: Vec<u64> = phases.keys().copied().collect();
                live.sort_unstable(); // deterministic pick under a seeded rng
                let victim = live[rng.below(live.len())];
                s.remove(victim);
                phases.remove(&victim);
                prompts.remove(&victim);
                resp.remove(&victim);
                // a cancelled sequence is excused from the
                // "preempted implies re-admitted" liveness property
                preempted_ever.remove(&victim);
                cancelled_count += 1;
                s.blocks
                    .check_invariants()
                    .map_err(|e| format!("step {step} post-cancel: {e}"))?;
            }
            one_tick(
                &mut s,
                &mut phases,
                &mut prompts,
                &mut resp,
                &mut preempted_ever,
                &mut readmitted,
                rng,
                false,
                step,
            )?;
        }
        // drain: no new arrivals; every sequence must complete
        let mut idle_ticks = 0usize;
        let mut step = 120usize;
        while !phases.is_empty() {
            step += 1;
            let n = one_tick(
                &mut s,
                &mut phases,
                &mut prompts,
                &mut resp,
                &mut preempted_ever,
                &mut readmitted,
                rng,
                true,
                step,
            )?;
            idle_ticks = if n == 0 { idle_ticks + 1 } else { 0 };
            prop_assert!(
                idle_ticks < 100,
                "drain stalled with {} sequences live",
                phases.len()
            );
            prop_assert!(step < 20_000, "drain did not converge");
        }
        prop_assert!(s.running.is_empty(), "scheduler retains finished sequences");
        prop_assert!(s.waiting.is_empty(), "scheduler retains cancelled waiters");
        for p in &preempted_ever {
            prop_assert!(readmitted.contains(p), "preempted seq {p} never re-admitted");
        }
        s.blocks.check_invariants().map_err(|e| format!("after drain: {e}"))?;
        prop_assert!(
            s.blocks.used() == 0,
            "{} blocks leaked after drain ({cancelled_count} cancellations)",
            s.blocks.used()
        );
        Ok(())
    });
}

/// Engine-level fuzz of the full session surface: random arrivals with
/// random priorities, deadlines and seeded sampling, random `cancel()`
/// calls at arbitrary phases — all over a tight pool with prefix caching
/// so cancellation interleaves with preemption, CoW adoption and
/// eviction.  Every handle must reach exactly one terminal event, every
/// block must come home, and the snapshot store must hold no orphans.
#[test]
fn fuzz_engine_cancellation_deadline_hygiene() {
    check("engine session fuzz", 10, |rng| {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 10 + rng.below(24), // tight: forced preemption
            max_running: 1 + rng.below(6),
            token_budget: 16 + rng.below(128),
            prefill_chunk: 8 + rng.below(48),
            queue_cap: 256,
            workers: 1,
            enable_prefix_cache: true,
            prefix_cache_blocks: 4 + rng.below(16),
            ..ServeConfig::default()
        };
        let mut e = Engine::new(
            cfg,
            Box::new(|_req: &Request| Box::new(EchoBackend::new(32)) as Box<dyn SeqBackend>),
        );
        let shared: Vec<u32> = (0..32).map(|_| rng.below(32) as u32).collect();
        let mut handles = Vec::new();
        let mut expect_deadline: HashSet<u64> = HashSet::new();
        let mut step = 0usize;
        let mut idle_ticks = 0usize;
        let mut to_submit = 6 + rng.below(8);
        let mut cancels_left = 4;
        loop {
            step += 1;
            prop_assert!(step < 20_000, "engine fuzz did not converge");
            if to_submit > 0 && rng.below(2) == 0 {
                to_submit -= 1;
                let mut prompt = if rng.below(2) == 0 { shared.clone() } else { Vec::new() };
                let len = 17 + rng.below(48);
                while prompt.len() < len {
                    prompt.push(rng.below(32) as u32);
                }
                let mut req = Request::new(prompt)
                    .max_new(1 + rng.below(12))
                    .priority(rng.below(3) as i32);
                if rng.below(4) == 0 {
                    let seed = rng.below(1 << 16) as u64;
                    req = req.sampling(kascade::config::SamplingParams::seeded(seed));
                }
                if rng.below(5) == 0 {
                    // an already-expired deadline: must fail, not hang
                    req = req.deadline_ms(0.0);
                }
                let h = e.submit(req.clone()).map_err(|err| format!("admission: {err}"))?;
                if req.deadline_ms.is_some() {
                    expect_deadline.insert(h.id());
                }
                handles.push(h);
            }
            if cancels_left > 0 && !handles.is_empty() && rng.below(4) == 0 {
                cancels_left -= 1;
                let h = &handles[rng.below(handles.len())];
                h.cancel(); // cancelling a terminal handle is a no-op
            }
            let n = e.tick();
            e.sched
                .blocks
                .check_invariants()
                .map_err(|err| format!("step {step}: {err}"))?;
            idle_ticks = if n == 0 { idle_ticks + 1 } else { 0 };
            prop_assert!(idle_ticks < 1000, "engine livelock");
            if to_submit == 0 && e.idle() {
                break;
            }
        }
        e.tick(); // drain pending index invalidations before the audit
        e.check_snapshot_invariants().map_err(|err| format!("snapshots: {err}"))?;
        prop_assert!(
            e.sched.blocks.used() == 0,
            "{} blocks leaked after drain",
            e.sched.blocks.used()
        );
        // every handle reaches exactly one terminal event, and streamed
        // tokens reassemble the completion byte-for-byte
        for h in &mut handles {
            let id = h.id();
            let mut streamed = Vec::new();
            let mut terminal: Option<Result<Completion, FailReason>> = None;
            while let Some(ev) = h.try_next() {
                match ev {
                    Event::Token { pos, tok } => {
                        prop_assert!(pos == streamed.len(), "seq {id}: token gap at {pos}");
                        streamed.push(tok);
                    }
                    Event::Done(c) => {
                        prop_assert!(terminal.is_none(), "seq {id}: two terminal events");
                        terminal = Some(Ok(c));
                    }
                    Event::Failed(f) => {
                        prop_assert!(terminal.is_none(), "seq {id}: two terminal events");
                        terminal = Some(Err(f));
                    }
                    Event::Started => {}
                }
            }
            match terminal {
                Some(Ok(c)) => {
                    prop_assert!(
                        c.tokens == streamed,
                        "seq {id}: streamed tokens diverge from completion"
                    );
                    prop_assert!(
                        !expect_deadline.contains(&id),
                        "seq {id} completed past an already-expired deadline"
                    );
                    prop_assert!(c.ttft_ms.is_some(), "seq {id}: completed without ttft");
                }
                Some(Err(f)) => {
                    if let Some(p) = f.partial() {
                        prop_assert!(
                            p.tokens == streamed,
                            "seq {id}: partial diverges from streamed tokens"
                        );
                        prop_assert!(
                            p.ttft_ms.is_some() == !p.tokens.is_empty(),
                            "seq {id}: ttft must be Some iff tokens were emitted"
                        );
                    }
                }
                None => return Err(format!("seq {id}: no terminal event")),
            }
        }
        Ok(())
    });
}

/// Deterministic backend whose logits depend only on every token it has
/// consumed — recompute after preemption or prefix-cache resume must
/// reproduce the continuation exactly.
struct EchoBackend {
    seen: Vec<u32>,
    vocab: usize,
}

impl EchoBackend {
    fn new(vocab: usize) -> Self {
        Self { seen: Vec::new(), vocab }
    }

    fn logits(&self) -> Vec<f32> {
        let mut h = 0xABCD_EF01_2345_6789u64;
        for &t in &self.seen {
            h = h.wrapping_add(t as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
            h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h ^= h >> 31;
        }
        let mut l = vec![0.0; self.vocab];
        l[(h % self.vocab as u64) as usize] = 1.0;
        l
    }
}

impl SeqBackend for EchoBackend {
    fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        self.seen.extend_from_slice(tokens);
        Some(self.logits())
    }

    fn decode(&mut self, token: u32) -> Vec<f32> {
        self.seen.push(token);
        self.logits()
    }

    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        if tokens > self.seen.len() {
            return None;
        }
        Some(Box::new(EchoBackend { seen: self.seen[..tokens].to_vec(), vocab: self.vocab }))
    }
}

fn echo_requests() -> Vec<Request> {
    let mut rng = Rng::new(42);
    // block-aligned prompts whose decode phase must cross block
    // boundaries (prompt + 20 > 64 tokens): any two concurrently running
    // sequences need 10+ blocks of an 8-block pool, so the tight run is
    // structurally guaranteed to preempt.  Half the requests share a
    // 32-token prefix so cache adoption and preemption interleave.
    let shared: Vec<u32> = (0..32).map(|_| rng.below(32) as u32).collect();
    (0..8u64)
        .map(|id| {
            let len = 48 + 16 * rng.below(2); // 48 or 64
            let mut prompt = if id % 2 == 0 { shared.clone() } else { Vec::new() };
            while prompt.len() < len {
                prompt.push(rng.below(32) as u32);
            }
            Request::new(prompt).max_new(20)
        })
        .collect()
}

fn run_engine(cfg: ServeConfig, reqs: &[Request]) -> (Vec<Completion>, u64, u64) {
    let mut engine = Engine::new(
        cfg,
        Box::new(|_req: &Request| Box::new(EchoBackend::new(32)) as Box<dyn SeqBackend>),
    );
    // serve the first request alone so its prefix is registered (and
    // still cached) before the shared-prefix followers contend for it
    let mut handles = vec![engine.submit(reqs[0].clone()).expect("admission")];
    let mut done = engine.run_to_completion(&mut handles);
    for r in &reqs[1..] {
        handles.push(engine.submit(r.clone()).expect("admission"));
    }
    done.extend(engine.run_to_completion(&mut handles));
    done.sort_by_key(|c| c.id);
    engine.sched.blocks.check_invariants().unwrap();
    (done, engine.metrics.preemptions, engine.metrics.prefix_hits)
}

#[test]
fn preempted_and_resumed_requests_complete_with_identical_outputs() {
    let reqs = echo_requests();
    // roomy baseline: no preemption, no caching
    let (baseline, base_preempts, _) = run_engine(
        ServeConfig {
            block_size: 16,
            num_blocks: 256,
            max_running: 8,
            token_budget: 128,
            prefill_chunk: 32,
            queue_cap: 64,
            workers: 1,
            ..ServeConfig::default()
        },
        &reqs,
    );
    assert_eq!(base_preempts, 0, "baseline must be unpressured");
    assert_eq!(baseline.len(), 8);
    for c in &baseline {
        assert_eq!(c.tokens.len(), 20);
    }
    // tight memory + prefix caching: decode OOM forces preemption while
    // followers adopt cached prefixes
    let (tight, tight_preempts, tight_hits) = run_engine(
        ServeConfig {
            block_size: 16,
            num_blocks: 8, // 128 tokens for ~450 tokens of demand
            max_running: 8,
            token_budget: 128,
            prefill_chunk: 32,
            queue_cap: 64,
            workers: 1,
            enable_prefix_cache: true,
            prefix_cache_blocks: 4,
            batched_decode: true,
            ..ServeConfig::default()
        },
        &reqs,
    );
    assert!(tight_preempts > 0, "scenario must actually preempt");
    assert!(tight_hits > 0, "shared prefixes must actually hit the cache");
    assert_eq!(tight.len(), 8, "every request completes despite preemption");
    for (a, b) in baseline.iter().zip(&tight) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.tokens, b.tokens,
            "request {} output changed under preemption + caching",
            a.id
        );
        assert_eq!(b.tokens.len(), 20);
    }
}
