//! Gateway end-to-end tests over loopback HTTP:
//!
//! * **prefix-affinity routing** — the pure selection rule holds its
//!   contract under randomized registry states, and a real registry
//!   pins a shared prefix chain to one replica while spreading
//!   unrelated chains;
//! * **graceful drain** — draining a replica with a live stream
//!   finishes that stream (`done`, never `failed`) before the replica
//!   retires, and the fleet keeps serving;
//! * **dead-replica failover** — after `POST /admin/kill`, traffic
//!   reroutes to the survivor with zero failed generations;
//! * **the acceptance run** — on identical shared-prefix traffic,
//!   affinity routing achieves strictly more prefix hits and saved
//!   prefill tokens than least-loaded-only routing, and a mid-run
//!   drain with concurrent in-flight streams loses nothing.

use kascade::config::ServeConfig;
use kascade::coordinator::{chain_hashes, Request, SeqBackend};
use kascade::gateway::{
    http, pick, ChainSummary, Gateway, GatewayConfig, GatewayServer, NdjsonStream, Registry,
    ReplicaHealth, ReplicaView,
};
use kascade::jsonutil::Json;
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::server::{BackendFactory, Server};
use kascade::workload::{TrafficGen, TrafficSpec};
use std::sync::Arc;
use std::time::Duration;

/// O(1) backend that supports prefix-cache snapshots, with an optional
/// per-decode pause so drain tests can observe streams in flight.
struct ForkableNull {
    tokens: usize,
    decode_pause: Duration,
}

impl ForkableNull {
    fn factory(decode_pause: Duration) -> BackendFactory {
        Box::new(move |_req: &Request| {
            Box::new(ForkableNull { tokens: 0, decode_pause }) as Box<dyn SeqBackend>
        })
    }
}

impl SeqBackend for ForkableNull {
    fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        self.tokens += tokens.len();
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        if !self.decode_pause.is_zero() {
            std::thread::sleep(self.decode_pause);
        }
        self.tokens += 1;
        vec![0.0, 1.0]
    }

    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        (tokens <= self.tokens).then(|| {
            Box::new(ForkableNull { tokens, decode_pause: self.decode_pause })
                as Box<dyn SeqBackend>
        })
    }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        block_size: 16,
        num_blocks: 1024,
        max_running: 16,
        token_budget: 1024,
        prefill_chunk: 128,
        queue_cap: 256,
        workers: 1,
        enable_prefix_cache: true,
        prefix_cache_blocks: 512,
        ..ServeConfig::default()
    }
}

fn replica(decode_pause: Duration) -> Server {
    Server::start(serve_cfg(), vec![ForkableNull::factory(decode_pause)])
}

fn gateway_server(replicas: usize, affinity: bool, decode_pause: Duration) -> GatewayServer {
    let gw = Gateway::new(GatewayConfig { affinity, ..GatewayConfig::default() });
    for _ in 0..replicas {
        gw.join(replica(decode_pause));
    }
    GatewayServer::bind("127.0.0.1:0", gw).expect("bind loopback")
}

fn gen_body(prompt: &[u32], max_new: usize) -> Vec<u8> {
    Json::obj(vec![
        ("prompt", Json::arr(prompt.iter().map(|&t| Json::num(t)))),
        ("max_new", Json::Num(max_new as f64)),
    ])
    .to_string()
    .into_bytes()
}

fn routed_replica(lines: &[String]) -> usize {
    let first = lines.first().expect("stream emitted no lines");
    let j = Json::parse(first).expect("routed line is JSON");
    assert_eq!(j.get("event").and_then(Json::as_str), Some("routed"));
    j.get("replica").and_then(Json::as_usize).expect("routed line carries replica id")
}

/// POST one generation, consume the stream fully; (routed replica, lines).
fn run_stream(addr: &str, prompt: &[u32], max_new: usize) -> (usize, Vec<String>) {
    let mut s = NdjsonStream::post(addr, "/v1/generate", &gen_body(prompt, max_new))
        .expect("post /v1/generate");
    assert_eq!(s.status, 200, "generate must stream 200");
    let lines = s.collect_lines().expect("read ndjson stream");
    (routed_replica(&lines), lines)
}

fn drain_replica(addr: &str, id: usize) -> String {
    let body = format!("{{\"replica\":{id}}}");
    let resp = http::request(addr, "POST", "/admin/drain", body.as_bytes()).expect("drain");
    assert_eq!(resp.status, 200, "drain {id}: {}", resp.text());
    resp.text().to_string()
}

/// Gracefully retire every live replica so worker threads join.
fn retire_all(gw: &Arc<Gateway>) {
    for s in gw.statuses() {
        if s.health != ReplicaHealth::Dead {
            gw.drain(s.id);
            gw.wait_drained(s.id, 10_000);
        }
    }
}

/// The pure selection rule holds its contract on randomized states:
/// deterministic, never picks a non-admitting replica, a strict score
/// leader wins regardless of load, and with affinity off the pick
/// minimizes in-flight load.
#[test]
fn affinity_pick_contract_property() {
    check("affinity pick contract", 200, |rng| {
        let n = 2 + rng.below(4);
        let views: Vec<ReplicaView> = (0..n)
            .map(|id| ReplicaView {
                id,
                admitting: rng.below(4) != 0, // admitting 3/4 of the time
                inflight: rng.below(8),
                routed: rng.below(16) as u64,
                score: rng.below(5),
            })
            .collect();
        let picked = pick(&views, true);
        prop_assert!(
            picked == pick(&views, true),
            "pick must be deterministic on identical views"
        );
        let admitting: Vec<&ReplicaView> = views.iter().filter(|v| v.admitting).collect();
        match picked {
            None => prop_assert!(
                admitting.is_empty(),
                "pick returned None with {} admitting replicas",
                admitting.len()
            ),
            Some(id) => {
                let v = &views[id];
                prop_assert!(v.admitting, "picked a non-admitting replica {id}");
                let best = admitting.iter().map(|v| v.score).max().unwrap_or(0);
                prop_assert!(
                    v.score == best,
                    "picked score {} but an admitting replica scores {best}",
                    v.score
                );
            }
        }
        // least-loaded mode ignores scores entirely
        if let Some(id) = pick(&views, false) {
            let min_load = admitting.iter().map(|v| v.inflight).min().unwrap_or(0);
            prop_assert!(
                views[id].inflight == min_load,
                "least-loaded picked inflight {} over minimum {min_load}",
                views[id].inflight
            );
        }
        Ok(())
    });
}

/// A summary scores exactly the *leading* cached run of a chain, so a
/// replica that saw `[A B]` scores 2 on `[A B C]` but 0 on `[C A B]`.
#[test]
fn summary_scores_are_prefix_depths() {
    let chain = chain_hashes(&(0..64).collect::<Vec<u32>>(), 16);
    assert_eq!(chain.len(), 4);
    let mut s = ChainSummary::new();
    s.observe_chain(&chain[..2]);
    assert_eq!(s.score(&chain), 2);
    let rotated = [chain[2], chain[0], chain[1]];
    assert_eq!(s.score(&rotated), 0, "a non-leading match must not count");
}

/// Against a real 3-replica registry: requests sharing a prefix chain
/// pin to one replica, unrelated chains spread, and a full drain
/// retires every replica.
#[test]
fn same_prefix_chain_pins_to_one_replica() {
    let mut reg = Registry::new(16);
    for _ in 0..3 {
        reg.join(replica(Duration::ZERO));
    }
    let groups: Vec<Vec<u32>> =
        (0u32..4).map(|g| (g * 1000..g * 1000 + 64).collect()).collect();
    // first contact decides each group's home replica
    let homes: Vec<usize> = groups
        .iter()
        .map(|g| reg.route(g, true).expect("3 replicas admit"))
        .collect();
    // every revisit — same prefix, varying tails — goes home again
    for (g, home) in groups.iter().zip(&homes) {
        for tail in 0u32..6 {
            let mut prompt = g.clone();
            prompt.extend([90_000 + tail, 90_100 + tail]);
            assert_eq!(
                reg.route(&prompt, true),
                Some(*home),
                "a shared prefix must keep routing to its home replica"
            );
        }
    }
    // four groups over three replicas must use more than one replica
    let distinct: std::collections::BTreeSet<usize> = homes.iter().copied().collect();
    assert!(distinct.len() > 1, "unrelated chains all landed on {homes:?}");
    // full retirement: nothing in flight, so one poll drains the fleet
    reg.drain_all();
    let retired = reg.poll_drains();
    assert_eq!(retired.len(), 3);
    assert_eq!(reg.admitting(), 0);
    assert_eq!(reg.route(&groups[0], true), None);
}

/// Draining the replica that owns a live stream lets the stream finish
/// (`done`, never `failed`), reports the replica dead, and leaves the
/// fleet serving from the survivor.
#[test]
fn graceful_drain_finishes_inflight_streams_over_loopback() {
    let server = gateway_server(2, true, Duration::from_millis(2));
    let addr = server.addr().to_string();
    let prompt: Vec<u32> = (0..48).collect();
    // a ~240ms stream (120 tokens, 2ms decode pause) stays in flight
    // while the drain lands on a second connection
    let mut s = NdjsonStream::post(&addr, "/v1/generate", &gen_body(&prompt, 120))
        .expect("post /v1/generate");
    assert_eq!(s.status, 200);
    let first = s.next_line().expect("read routed line").expect("routed line");
    let routed = routed_replica(&[first]);
    let drain_text = drain_replica(&addr, routed);
    let dj = Json::parse(&drain_text).expect("drain response is JSON");
    assert!(matches!(dj.get("started"), Some(Json::Bool(true))));
    assert_eq!(dj.get("health").and_then(Json::as_str), Some("dead"));
    // the stream the drain waited on ran to completion
    let lines = s.collect_lines().expect("finish the drained stream");
    assert!(lines.iter().all(|l| !l.contains("\"failed\"")), "drain failed a stream: {lines:?}");
    assert!(lines.last().expect("stream body").contains("\"done\""));
    // the fleet still serves, from the other replica
    let hz = http::request(&addr, "GET", "/healthz", b"").expect("healthz");
    assert_eq!(hz.status, 200);
    let (rerouted, lines) = run_stream(&addr, &prompt, 4);
    assert_ne!(rerouted, routed, "a drained replica must not admit");
    assert!(lines.last().expect("stream body").contains("\"done\""));
    let gw = server.gateway();
    assert_eq!(gw.counters().generate_failed, 0);
    retire_all(&gw);
    server.stop();
}

/// `POST /admin/kill` aborts a replica outright; the gateway routes
/// around the dead slot and later generations still complete.
#[test]
fn dead_replica_failover_over_loopback() {
    let server = gateway_server(2, true, Duration::ZERO);
    let addr = server.addr().to_string();
    let prompt: Vec<u32> = (100..148).collect();
    let (first, lines) = run_stream(&addr, &prompt, 8);
    assert!(lines.last().expect("stream body").contains("\"done\""));
    let body = format!("{{\"replica\":{first}}}");
    let resp = http::request(&addr, "POST", "/admin/kill", body.as_bytes()).expect("kill");
    assert_eq!(resp.status, 200);
    assert!(resp.text().contains("\"dead\""));
    for i in 0..3u32 {
        let base = 200 + i * 50;
        let p: Vec<u32> = (base..base + 40).collect();
        let (r, lines) = run_stream(&addr, &p, 6);
        assert_ne!(r, first, "traffic must route around the killed replica");
        assert!(lines.last().expect("stream body").contains("\"done\""));
    }
    let hz = http::request(&addr, "GET", "/healthz", b"").expect("healthz");
    assert_eq!(hz.status, 200, "one admitting replica keeps the fleet healthy");
    let gw = server.gateway();
    assert_eq!(gw.counters().kills, 1);
    retire_all(&gw);
    server.stop();
}

/// Drive identical seeded shared-prefix traffic through a 2-replica
/// gateway and return `(prefix_hits, saved_prefill_tokens,
/// generate_failed)` from the post-drain fleet metrics.
///
/// Three prefix groups over two replicas: least-loaded rotation
/// necessarily re-misses each group on the second replica it touches,
/// while affinity keeps every group home after its warm-up miss.
fn run_prefix_workload(affinity: bool) -> (u64, u64, u64) {
    let server = gateway_server(2, affinity, Duration::ZERO);
    let addr = server.addr().to_string();
    let groups: Vec<Vec<u32>> =
        (0u32..3).map(|g| (g * 1000..g * 1000 + 64).collect()).collect();
    let mut completions = 0u64;
    let mut consume = |prompt: &[u32]| {
        let (_, lines) = run_stream(&addr, prompt, 4);
        assert!(lines.last().expect("stream body").contains("\"done\""));
        completions += 1;
        // let the handler drop its in-flight guard and bump counters, so
        // the next route sees the settled registry state
        std::thread::sleep(Duration::from_millis(3));
    };
    // warm-up: each group's first contact seeds one replica's cache
    for g in &groups {
        consume(g);
    }
    // steady traffic: group prefixes with unique tails, round-robin
    for i in 0u32..24 {
        let mut prompt = groups[(i % 3) as usize].clone();
        prompt.extend([9_000 + i, 9_100 + i, 9_200 + i, 9_300 + i]);
        consume(&prompt);
    }
    drop(consume);
    // retire both replicas: engine-side counters only reach the fleet
    // view once their replica drains
    for id in [0usize, 1] {
        let text = drain_replica(&addr, id);
        assert!(text.contains("\"dead\""), "drain must retire replica {id}: {text}");
    }
    let m = http::request(&addr, "GET", "/metrics", b"").expect("metrics");
    assert_eq!(m.status, 200);
    let j = Json::parse(m.text()).expect("metrics JSON");
    let num = |section: &str, key: &str| {
        j.get(section)
            .and_then(|s| s.get(key))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("metrics missing {section}.{key}")) as u64
    };
    assert_eq!(num("fleet", "requests_done"), completions);
    assert_eq!(num("gateway", "generate_ok"), completions);
    let out = (
        num("fleet", "prefix_hits"),
        num("fleet", "saved_prefill_tokens"),
        num("gateway", "generate_failed"),
    );
    server.stop();
    out
}

/// The acceptance run: same seeded shared-prefix traffic, two-replica
/// fleet — affinity routing must beat least-loaded-only routing on
/// both prefix hits and saved prefill tokens, with zero failures.
#[test]
fn affinity_beats_least_loaded_on_shared_prefix_traffic() {
    let (hits_aff, saved_aff, failed_aff) = run_prefix_workload(true);
    let (hits_ll, saved_ll, failed_ll) = run_prefix_workload(false);
    assert_eq!(failed_aff, 0);
    assert_eq!(failed_ll, 0);
    assert!(
        hits_aff > hits_ll,
        "affinity must strictly beat least-loaded on prefix hits: {hits_aff} vs {hits_ll}"
    );
    assert!(
        saved_aff > saved_ll,
        "affinity must strictly beat least-loaded on saved prefill \
         tokens: {saved_aff} vs {saved_ll}"
    );
    // affinity pays exactly one warm-up miss per group, then always hits
    assert_eq!(hits_aff, 24, "every steady request must hit its home replica");
}

/// Six concurrent streams, then a drain of replica 0 while its streams
/// are demonstrably in flight: every stream must still end in `done`
/// and the gateway must count zero failed generations.
#[test]
fn mid_run_drain_completes_all_inflight_streams() {
    let server = gateway_server(2, true, Duration::from_millis(2));
    let addr = server.addr().to_string();
    let gw = server.gateway();
    let clients: Vec<_> = (0u32..6)
        .map(|i| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let base = 10_000 + i * 100;
                let prompt: Vec<u32> = (base..base + 40).collect();
                let mut s = NdjsonStream::post(&addr, "/v1/generate", &gen_body(&prompt, 120))
                    .expect("post /v1/generate");
                assert_eq!(s.status, 200);
                let lines = s.collect_lines().expect("consume stream");
                (routed_replica(&lines), lines)
            })
        })
        .collect();
    // wait until streams are observably in flight, then drain under them
    let mut waited = 0;
    while gw.statuses().iter().all(|s| s.inflight == 0) {
        std::thread::sleep(Duration::from_millis(2));
        waited += 1;
        assert!(waited < 2000, "no stream ever went in flight");
    }
    let text = drain_replica(&addr, 0);
    assert!(text.contains("\"dead\""), "drain must complete: {text}");
    let mut on_drained = 0;
    for c in clients {
        let (routed, lines) = c.join().expect("client thread");
        if routed == 0 {
            on_drained += 1;
        }
        let last = lines.last().expect("stream body");
        assert!(last.contains("\"done\""), "stream must finish cleanly, got {last}");
        assert!(lines.iter().all(|l| !l.contains("\"failed\"")), "lost a stream: {lines:?}");
    }
    assert!(on_drained >= 1, "the drain must have raced at least one in-flight stream");
    assert_eq!(gw.counters().generate_failed, 0);
    retire_all(&gw);
    server.stop();
}

/// The SLO traffic harness drives the gateway over loopback HTTP: a
/// seeded [`TrafficGen`] stream (all three tenant classes) runs end to
/// end, every stream completes, and the post-drain fleet view accounts
/// for every completion exactly once.
#[test]
fn traffic_gen_drives_the_gateway_over_loopback() {
    let server = gateway_server(2, true, Duration::ZERO);
    let addr = server.addr().to_string();
    let mut gen = TrafficGen::new(TrafficSpec {
        seed: 7,
        base_rate: 0.5,
        prompt_cap: 256,
        ..TrafficSpec::default()
    });
    let mut sent = 0u64;
    for _ in 0..40 {
        for r in gen.next_tick() {
            let body = Json::obj(vec![
                ("prompt", Json::arr(r.prompt.iter().map(|&t| Json::num(t)))),
                ("max_new", Json::Num(r.max_new.clamp(1, 8) as f64)),
                ("tenant", Json::Num(r.tenant as f64)),
            ]);
            let mut s = NdjsonStream::post(&addr, "/v1/generate", body.to_string().as_bytes())
                .expect("post traffic request");
            assert_eq!(s.status, 200);
            let lines = s.collect_lines().expect("consume stream");
            assert!(lines.last().expect("stream body").contains("\"done\""));
            sent += 1;
        }
    }
    assert!(sent >= 10, "the seeded stream produced only {sent} requests");
    // settle the last handler, then retire the fleet for the full view
    std::thread::sleep(Duration::from_millis(20));
    for id in [0usize, 1] {
        let text = drain_replica(&addr, id);
        assert!(text.contains("\"dead\""), "drain must retire replica {id}: {text}");
    }
    let m = http::request(&addr, "GET", "/metrics", b"").expect("metrics");
    assert_eq!(m.status, 200);
    let j = Json::parse(m.text()).expect("metrics JSON");
    let fleet = |key: &str| {
        j.get("fleet")
            .and_then(|f| f.get(key))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("metrics missing fleet.{key}")) as u64
    };
    assert_eq!(fleet("requests_done"), sent);
    assert!(fleet("tokens_out") >= sent, "every request emits at least one token");
    let gw = server.gateway();
    assert_eq!(gw.counters().generate_ok, sent);
    assert_eq!(gw.counters().generate_failed, 0);
    server.stop();
}
