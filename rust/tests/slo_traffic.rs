//! SLO-facing scheduler properties under traffic:
//!
//! * **chunked-prefill interleaving** — while a ≥128k-token prefill is
//!   in flight, no tick with live decoders schedules more prefill
//!   tokens than `decode_guard_prefill_tokens`, and every live decoder
//!   still gets exactly one token per tick (no decode stall);
//! * **fair-share admission** — under a 10:1 tenant load skew the
//!   minority tenant's requests do not starve behind the flood;
//! * **traffic-driven serving** — a seeded [`TrafficGen`] stream runs
//!   end to end through the engine and the TTFT/TPOT percentile
//!   surface is populated;
//! * **worker-boundary regressions** — the same decode-guard and
//!   fair-share properties hold when requests cross a multi-worker
//!   [`Server`]'s channel boundary instead of ticking an engine
//!   directly (the gateway serves replicas through this path).

use kascade::config::ServeConfig;
use kascade::coordinator::{Event, Request, SeqBackend, SeqPhase, ServeMetrics};
use kascade::server::{BackendFactory, Engine, Server};
use kascade::workload::{TrafficGen, TrafficSpec};
use std::time::Duration;

/// O(1)-per-call backend: the test measures scheduling, not compute.
struct NullBackend;

impl SeqBackend for NullBackend {
    fn prefill_chunk(&mut self, _tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        vec![0.0, 1.0]
    }
}

fn null_engine(cfg: ServeConfig) -> Engine {
    Engine::new(cfg, Box::new(|_req: &Request| Box::new(NullBackend) as Box<dyn SeqBackend>))
}

/// Null backend with a fixed per-decode pause: bounds the worker's tick
/// rate so wall-clock test orchestration (submit ordering across the
/// channel boundary) cannot be outrun by a free-running engine thread.
struct PausingBackend {
    pause_us: u64,
}

impl SeqBackend for PausingBackend {
    fn prefill_chunk(&mut self, _tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        Some(vec![0.0, 1.0])
    }

    fn decode(&mut self, _token: u32) -> Vec<f32> {
        std::thread::sleep(Duration::from_micros(self.pause_us));
        vec![0.0, 1.0]
    }
}

fn pausing_factory(pause_us: u64) -> BackendFactory {
    Box::new(move |_req: &Request| Box::new(PausingBackend { pause_us }) as Box<dyn SeqBackend>)
}

/// A ≥128k-token prefill interleaves with live decoders: per tick the
/// prefill advances by at most the guard, and every decoder advances by
/// exactly one token — the decode stream never stalls behind the ingest.
#[test]
fn huge_prefill_cannot_stall_decode_ticks() {
    const GUARD: usize = 64;
    const BIG: usize = 131_072; // 128k tokens
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 9216, // 128k prompt + decoder growth
        max_running: 8,
        token_budget: 512,
        prefill_chunk: 256,
        queue_cap: 64,
        workers: 1,
        decode_guard_prefill_tokens: Some(GUARD),
        ..ServeConfig::default()
    };
    let mut e = null_engine(cfg);
    // four live decoders, long enough to outlast the whole big prefill
    let mut decoders = Vec::new();
    for _ in 0..4 {
        decoders.push(e.submit(Request::new(vec![7; 32]).max_new(3000)).unwrap());
    }
    let mut guard = 0;
    while !decoders.iter().map(|h| h.id()).all(|id| {
        matches!(e.seqs.get(&id).map(|s| s.phase), Some(SeqPhase::Decoding))
    }) {
        e.tick();
        guard += 1;
        assert!(guard < 50, "decoders never reached decode phase");
    }
    let big = e.submit(Request::new(vec![9; BIG]).max_new(1)).unwrap();
    let big_id = big.id();
    let mut ticks = 0usize;
    let mut last_done = 0usize;
    let mut emitted: Vec<usize> =
        decoders.iter().map(|h| e.seqs[&h.id()].emitted.len()).collect();
    loop {
        let phase = e.seqs.get(&big_id).map(|s| s.phase);
        let done = match phase {
            Some(SeqPhase::Waiting) | None => 0,
            Some(SeqPhase::Prefilling { done }) => done,
            Some(SeqPhase::Decoding) | Some(SeqPhase::Finished) => break,
        };
        e.tick();
        ticks += 1;
        assert!(ticks < 3000, "prefill never completed");
        // the guard bounds the prefill slice taken while decoders live
        let now = match e.seqs.get(&big_id).map(|s| s.phase) {
            Some(SeqPhase::Prefilling { done }) => done,
            Some(SeqPhase::Decoding) | Some(SeqPhase::Finished) => BIG,
            _ => 0,
        };
        assert!(
            now - done <= GUARD,
            "tick {ticks}: prefill advanced {} > guard {GUARD}",
            now - done
        );
        last_done = now;
        // every decoder advanced by exactly one token this tick
        for (i, h) in decoders.iter().enumerate() {
            let n = e.seqs[&h.id()].emitted.len();
            assert_eq!(
                n,
                emitted[i] + 1,
                "tick {ticks}: decoder {i} stalled behind the 128k prefill"
            );
            emitted[i] = n;
        }
    }
    assert!(last_done >= BIG - GUARD, "prefill actually ran to completion");
    assert!(
        ticks >= BIG / GUARD,
        "a guarded 128k prefill must take >= {} ticks, took {ticks}",
        BIG / GUARD
    );
    e.sched.blocks.check_invariants().unwrap();
}

/// 10:1 load skew: tenant A floods 40 requests, tenant B submits 4.
/// With fair-share on, B's requests interleave with the flood instead
/// of queueing behind all of it; with fair-share off (FCFS) they finish
/// dead last.  Completion-order positions make the contrast exact.
#[test]
fn fair_share_prevents_starvation_under_skew() {
    let run = |fair_share: bool| -> Vec<u32> {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 256,
            max_running: 2,
            token_budget: 128,
            prefill_chunk: 64,
            queue_cap: 64,
            workers: 1,
            fair_share,
            ..ServeConfig::default()
        };
        let mut e = null_engine(cfg);
        let mut handles = Vec::new();
        for _ in 0..40 {
            handles.push(e.submit(Request::new(vec![1; 32]).max_new(4).tenant(1)).unwrap());
        }
        for _ in 0..4 {
            handles.push(e.submit(Request::new(vec![2; 32]).max_new(4).tenant(2)).unwrap());
        }
        // completion order by tenant
        let mut order = Vec::new();
        let mut guard = 0;
        while !e.idle() {
            let did = e.tick();
            guard = if did == 0 { guard + 1 } else { 0 };
            assert!(guard < 1000, "livelock");
            for (i, h) in handles.iter_mut().enumerate() {
                while let Some(ev) = h.try_next() {
                    if matches!(ev, Event::Done(_)) {
                        order.push(if i < 40 { 1u32 } else { 2u32 });
                    }
                }
            }
        }
        assert_eq!(order.len(), 44);
        order
    };
    let fcfs = run(false);
    assert_eq!(&fcfs[40..], &[2, 2, 2, 2], "FCFS serves the minority tenant dead last");
    let fair = run(true);
    let last_b = fair.iter().rposition(|&t| t == 2).unwrap();
    assert!(
        last_b < 16,
        "fair-share must interleave tenant B with the flood; last B finished at {last_b}"
    );
}

/// A seeded traffic stream (bursty arrivals, heavy tails, all three
/// tenant classes) runs end to end; the percentile surface the SLO gate
/// reads is populated and ordered.
#[test]
fn traffic_stream_drives_the_engine_end_to_end() {
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 4096,
        max_running: 16,
        token_budget: 1024,
        prefill_chunk: 256,
        queue_cap: 256,
        workers: 1,
        fair_share: true,
        decode_guard_prefill_tokens: Some(128),
        ..ServeConfig::default()
    };
    let mut e = null_engine(cfg);
    let mut gen = TrafficGen::new(TrafficSpec {
        seed: 1234,
        base_rate: 0.5,
        prompt_cap: 512,
        ..TrafficSpec::default()
    });
    let mut handles = Vec::new();
    for _ in 0..200 {
        for r in gen.next_tick() {
            let req = Request::new(r.prompt).max_new(r.max_new).tenant(r.tenant);
            if let Ok(h) = e.submit(req) {
                handles.push(h);
            }
        }
        e.tick();
    }
    let mut done = e.run_to_completion(&mut handles);
    // completions that landed during the arrival loop are still queued
    // on their handles — run_to_completion only drains while ticking
    for h in &mut handles {
        while let Some(ev) = h.try_next() {
            if let Event::Done(c) = ev {
                done.push(c);
            }
        }
    }
    assert!(done.len() >= 20, "traffic produced only {} completions", done.len());
    assert_eq!(done.len() as u64, e.metrics.requests_done);
    let m = &e.metrics;
    assert!(m.ttft_percentile(50.0) > 0.0);
    assert!(m.ttft_percentile(95.0) >= m.ttft_percentile(50.0));
    assert!(m.tpot_percentile(95.0) >= m.tpot_percentile(50.0));
    assert!(m.tpot_percentile(99.0) >= m.tpot_percentile(95.0));
    assert!(m.prefill_tokens_per_tick.max() > 0.0);
    e.sched.blocks.check_invariants().unwrap();
}

/// The decode-tick guard survives the worker boundary: a multi-worker
/// [`Server`] runs a live decoder and a 16k-token prefill pinned to the
/// same worker via session affinity, and the merged per-worker metrics
/// show no tick that scheduled more prefill tokens than the guard.
#[test]
fn decode_guard_survives_the_worker_boundary() {
    const GUARD: usize = 64;
    const BIG: usize = 16_384;
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 4096,
        max_running: 8,
        token_budget: 512,
        prefill_chunk: 256,
        queue_cap: 64,
        workers: 2,
        decode_guard_prefill_tokens: Some(GUARD),
        ..ServeConfig::default()
    };
    let mut srv = Server::start(cfg, vec![pausing_factory(50), pausing_factory(50)]);
    // same session => same worker: decoder and ingest meet in one
    // engine, after crossing the submit/event channel boundary
    const SESSION: u64 = 42;
    let mut dec = srv
        .submit(Request::new(vec![7; 32]).max_new(1_000_000), Some(SESSION))
        .expect("submit decoder");
    // the decoder must demonstrably decode before the ingest arrives
    let mut saw_token = false;
    for _ in 0..100 {
        match dec.next_timeout(Duration::from_millis(100)) {
            Some(Event::Token { .. }) => {
                saw_token = true;
                break;
            }
            Some(_) => {}
            None => {}
        }
    }
    assert!(saw_token, "decoder never produced a token");
    let mut big = srv
        .submit(Request::new(vec![9; BIG]).max_new(1), Some(SESSION))
        .expect("submit 16k ingest");
    let done = big.wait(Duration::from_secs(120)).expect("guarded ingest completes");
    assert_eq!(done.tokens.len(), 1);
    // tear the decoder down and count what it streamed meanwhile
    dec.cancel();
    let mut decoded = 0usize;
    loop {
        match dec.next_timeout(Duration::from_secs(10)) {
            Some(Event::Token { .. }) => decoded += 1,
            Some(Event::Done(_)) | Some(Event::Failed(_)) => break,
            Some(_) => {}
            None => panic!("decoder stream went silent after cancel"),
        }
    }
    // a guarded 16k ingest spans >= BIG/GUARD ticks, one decode each
    assert!(
        decoded + 10 >= BIG / GUARD,
        "decoder starved under the ingest: {decoded} tokens for {} guarded ticks",
        BIG / GUARD
    );
    let parts = srv.shutdown();
    assert_eq!(parts.len(), 2);
    let merged = ServeMetrics::merge(&parts);
    assert_eq!(merged.threads, 2, "both workers report into the merged view");
    let worst = merged.prefill_tokens_per_tick.max();
    assert!(worst > 0.0);
    assert!(
        worst <= GUARD as f64,
        "a tick scheduled {worst} prefill tokens past the {GUARD}-token guard"
    );
}

/// Fair-share admission survives the worker boundary: under the same
/// 10:1 tenant skew as the engine-level test, tenant B's completion
/// TTFTs interleave with the flood when fair-share is on, and trail the
/// entire flood under FCFS — observed via `Server` handles only.
#[test]
fn fair_share_survives_the_worker_boundary() {
    let run = |fair_share: bool| -> (Vec<f64>, Vec<f64>) {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 512,
            max_running: 2,
            token_budget: 128,
            prefill_chunk: 64,
            queue_cap: 64,
            workers: 1,
            fair_share,
            ..ServeConfig::default()
        };
        let mut srv = Server::start(cfg, vec![pausing_factory(100)]);
        let mut handles = Vec::new();
        for _ in 0..40 {
            handles.push(
                srv.submit(Request::new(vec![1; 32]).max_new(4).tenant(1), None)
                    .expect("submit flood request"),
            );
        }
        for _ in 0..4 {
            handles.push(
                srv.submit(Request::new(vec![2; 32]).max_new(4).tenant(2), None)
                    .expect("submit minority request"),
            );
        }
        let mut ttft = Vec::new();
        for h in &mut handles {
            let c = h.wait(Duration::from_secs(60)).expect("request completes");
            ttft.push(c.ttft_ms.expect("completion carries ttft"));
        }
        srv.shutdown();
        (ttft[..40].to_vec(), ttft[40..].to_vec())
    };
    // FCFS: tenant B queues behind the whole flood
    let (a, b) = run(false);
    let mut a_sorted = a;
    a_sorted.sort_by(f64::total_cmp);
    let a_median = a_sorted[a_sorted.len() / 2];
    let b_min = b.iter().copied().fold(f64::INFINITY, f64::min);
    assert!(
        b_min > a_median,
        "FCFS should leave tenant B behind the flood: B min {b_min}ms vs A median {a_median}ms"
    );
    // fair-share: B interleaves with the flood instead of trailing it
    let (a, b) = run(true);
    let a_max = a.iter().copied().fold(0.0_f64, f64::max);
    let b_max = b.iter().copied().fold(0.0_f64, f64::max);
    assert!(
        b_max < a_max,
        "fair-share must interleave tenant B with the flood: \
         B max {b_max}ms vs A max {a_max}ms"
    );
}
