//! SIMD dispatch equivalence suite (docs/perf.md § SIMD): the
//! vectorized tile kernels must be BITWISE-equal to the scalar
//! reference at every level runtime detection can hand out, for every
//! KV storage mode, including ragged tail tiles — the lane structure
//! pins the accumulation order (4-lane partial sums, no FMA, scalar
//! tails), so "same math, faster" is testable as exact equality, not a
//! tolerance.  Also pins the `KASCADE_FORCE_SCALAR` escape hatch the
//! forced-fallback CI leg runs this suite under.

use kascade::attention::KvCache;
use kascade::config::{KvDtype, TopKRule};
use kascade::kascade::KascadePlan;
use kascade::model::SynthSpec;
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::simd::{available_levels, detect, SimdLevel};
use kascade::sparse::KascadePolicy;
use kascade::workload::WorkloadGen;
use std::sync::Arc;

const DTYPES: [KvDtype; 4] = [KvDtype::F32, KvDtype::F16, KvDtype::Int8, KvDtype::Int4];

/// Seeded cache of `len` positions (ragged lengths leave a partial
/// staging tile in the compressed modes).
fn fill(n_kv: usize, d: usize, cap: usize, len: usize, dtype: KvDtype, seed: u64) -> KvCache {
    let mut rng = kascade::tensor::Rng::new(seed);
    let mut cache = KvCache::with_opts(n_kv, d, cap, 16, dtype);
    let mut k = vec![0.0f32; n_kv * d];
    let mut v = vec![0.0f32; n_kv * d];
    for _ in 0..len {
        rng.fill_normal(&mut k, 0.8);
        rng.fill_normal(&mut v, 1.0);
        cache.push(&k, &v);
    }
    cache
}

/// Every (level x dtype) cell of score_tile/attend_tile is bitwise-equal
/// to the forced-scalar run over random tiles and ragged tail lengths.
#[test]
fn prop_tile_kernels_bitwise_equal_at_every_level() {
    let levels = available_levels();
    assert_eq!(levels[0], SimdLevel::Scalar, "scalar is always level 0");
    check("simd tile kernels vs scalar", 12, |rng| {
        let n_kv = 1 + rng.below(2);
        let d = 16 * (1 + rng.below(2)); // 16 or 32 — even, int4-packable
        let len = 17 + rng.below(120); // always spans a ragged tail case
        let cap = 160;
        let mut q = vec![0.0f32; d];
        rng.fill_normal(&mut q, 1.0);
        // positive weights with a few exact zeros to exercise the skip
        let w: Vec<f32> = (0..16)
            .map(|i| if i % 7 == 3 { 0.0 } else { 0.01 + rng.uniform() })
            .collect();
        for dtype in DTYPES {
            let mut cache = fill(n_kv, d, cap, len, dtype, 0x51D ^ len as u64);
            let tiles = len.div_ceil(16);
            // clamp mid-tile on odd iterations to exercise the n clamp
            let upto = if len % 2 == 1 { len - len.min(5) } else { len };
            let mut base_scores: Vec<Vec<f32>> = Vec::new();
            let mut base_acc: Vec<Vec<f32>> = Vec::new();
            for &level in &levels {
                cache.set_simd_level(level);
                for h in 0..n_kv {
                    for tile in 0..tiles {
                        let mut scores = vec![0.0f32; 16];
                        let mut acc = vec![0.0f32; d];
                        let n = cache.score_tile(h, tile, upto, &q, 0.125, &mut scores);
                        let m = cache.attend_tile(h, tile, upto, &w, &mut acc);
                        prop_assert!(n == m, "score/attend row counts disagree");
                        let slot = h * tiles + tile;
                        if level == SimdLevel::Scalar {
                            base_scores.push(scores);
                            base_acc.push(acc);
                        } else {
                            for (j, (a, b)) in
                                base_scores[slot].iter().zip(&scores).enumerate()
                            {
                                prop_assert!(
                                    a.to_bits() == b.to_bits(),
                                    "{} {} h{h} tile{tile} score[{j}]: {a} != {b}",
                                    dtype.label(),
                                    level.label()
                                );
                            }
                            for (j, (a, b)) in base_acc[slot].iter().zip(&acc).enumerate() {
                                prop_assert!(
                                    a.to_bits() == b.to_bits(),
                                    "{} {} h{h} tile{tile} acc[{j}]: {a} != {b}",
                                    dtype.label(),
                                    level.label()
                                );
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Engine-level equivalence: a full Kascade prefill + decode replay
/// produces bitwise-identical logits at every available level, for
/// every KV storage mode — the whole per-step pipeline (pooled scoring,
/// Top-k, sparse attend, softmax rescale) rides the same dispatch.
#[test]
fn decode_logits_bitwise_equal_at_every_level() {
    let mut spec = SynthSpec::eval_base(0x51D);
    spec.cfg.n_layers = 4;
    spec.block_starts = vec![1];
    let model = Arc::new(spec.build());
    let mut gen = WorkloadGen::new(&spec, 0xFA11);
    let prompt = gen.dev_prompt(72); // ragged: 4 full tiles + 8 staged rows
    let plan = || KascadePlan::from_anchors(4, 4, vec![0, 2], TopKRule::new(0.25, 8));
    for dtype in DTYPES {
        let run_at = |level: SimdLevel| -> Vec<f32> {
            let mut st = model.new_state_with_dtype(256, dtype);
            for c in &mut st.caches {
                c.set_simd_level(level);
            }
            let mut pol = KascadePolicy::new(plan());
            let (mut all, _) = model.prefill(&prompt, &mut st, &mut pol, None);
            for t in [3u32, 5, 7, 11, 13] {
                all.extend(model.decode_step(t, &mut st, &mut pol));
            }
            all
        };
        let scalar = run_at(SimdLevel::Scalar);
        for level in available_levels() {
            let got = run_at(level);
            assert_eq!(scalar.len(), got.len());
            for (i, (a, b)) in scalar.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "dtype {} level {} logit {i}: {a} != {b}",
                    dtype.label(),
                    level.label()
                );
            }
        }
    }
}

/// The `KASCADE_FORCE_SCALAR` override the forced-fallback CI leg sets:
/// when present (non-empty, not "0") detection must resolve to Scalar;
/// either way detection is stable and Scalar leads the level list.
#[test]
fn force_scalar_env_pins_detection() {
    let forced = std::env::var("KASCADE_FORCE_SCALAR")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if forced {
        assert_eq!(detect(), SimdLevel::Scalar, "KASCADE_FORCE_SCALAR must pin Scalar");
    }
    assert_eq!(detect(), detect(), "detection must be stable");
    let levels = available_levels();
    assert_eq!(levels[0], SimdLevel::Scalar);
    // the override pins what the engine gets, not what the equivalence
    // suites may iterate — Scalar is always present regardless
    assert!(levels.contains(&detect()) || forced);
}
