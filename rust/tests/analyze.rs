//! The analyzer's own fixture corpus (`tests/analyze_fixtures/`): every
//! rule family must fire on its planted violation and stay silent on
//! the matching false-positive trap — plus a live run proving the
//! repo's own sources are clean (docs/analysis.md).

use kascade::analyze::{run, Config, Report};
use std::path::PathBuf;

fn fixture_report() -> Report {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/analyze_fixtures");
    run(&Config::bare(root), false).expect("fixture corpus must be readable")
}

#[test]
fn every_rule_fires_on_its_fixture() {
    let r = fixture_report();
    let count = |rule: &str, file: &str| {
        r.findings.iter().filter(|f| f.rule == rule && f.file == file).count()
    };
    assert_eq!(count("determinism", "determinism.rs"), 2, "{:#?}", r.findings);
    assert_eq!(count("hot-path-alloc", "hot_alloc.rs"), 1, "{:#?}", r.findings);
    assert_eq!(count("panic-path", "panic.rs"), 2, "{:#?}", r.findings);
    assert_eq!(count("panic-path", "allow_grammar.rs"), 1, "{:#?}", r.findings);
    assert_eq!(count("allow-grammar", "allow_grammar.rs"), 1, "{:#?}", r.findings);
    assert_eq!(count("api-surface", "api_arity.rs"), 1, "{:#?}", r.findings);
    assert_eq!(r.findings.len(), 8, "no extra findings: {:#?}", r.findings);
}

#[test]
fn traps_stay_silent_and_reasoned_allows_are_consumed() {
    let r = fixture_report();
    // the reasoned allow in allow_grammar.rs was used -> no stale warning
    assert!(r.warnings.is_empty(), "{:?}", r.warnings);
    // no trap fn is ever named in a finding
    let traps = ["keyed_lookup", "setup_accumulate", "slot_checked", "fire_audited", "goodcall"];
    for f in &r.findings {
        for trap in traps {
            assert!(!f.msg.contains(trap), "trap {trap} flagged: {f:?}");
        }
    }
}

/// `make analyze` in test form: the shipped sources carry no findings
/// and no stale allow annotations.
#[test]
fn the_repo_itself_is_clean() {
    let rust_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let r = run(&Config::kascade(&rust_dir), false).expect("repo sources must be readable");
    assert!(r.findings.is_empty(), "repo findings: {:#?}", r.findings);
    assert!(r.warnings.is_empty(), "stale allows: {:?}", r.warnings);
}
