//! Zero-allocation guarantee of the decode hot loop: once every scratch
//! buffer has reached steady-state capacity, `Model::decode_batch` (the
//! path every engine tick's decodes run through) performs NO heap
//! allocations per decoded token — scores, pooled planes, Top-k staging,
//! selections and logits all live in reused arenas.
//!
//! Counted with a global allocator wrapper.  This file holds a single
//! test so no sibling test thread can allocate during the measured
//! window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

use kascade::config::{ModelConfig, TopKRule};
use kascade::kascade::KascadePlan;
use kascade::model::{BatchScratch, DecodeReq, Model, Weights};
use kascade::sparse::{DensePolicy, KascadePolicy, SparsePolicy};
use kascade::tensor::Rng;

fn random_model(seed: u64) -> Model {
    let cfg = ModelConfig {
        n_layers: 4,
        d_model: 32,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        d_ff: 64,
        vocab: 64,
        rope_theta: 10000.0,
        rope: true,
    };
    let mut w = Weights::zeros(&cfg);
    let mut r = Rng::new(seed);
    r.fill_normal(&mut w.w_e, 0.3);
    for lw in &mut w.layers {
        r.fill_normal(&mut lw.wq, 0.18);
        r.fill_normal(&mut lw.wk, 0.18);
        r.fill_normal(&mut lw.wv, 0.18);
        r.fill_normal(&mut lw.wo, 0.18);
        r.fill_normal(&mut lw.w1, 0.18);
        r.fill_normal(&mut lw.w3, 0.18);
        r.fill_normal(&mut lw.w2, 0.12);
    }
    r.fill_normal(&mut w.w_u, 0.18);
    Model::new(cfg, w)
}

#[test]
fn decode_batch_steady_state_allocates_nothing() {
    let m = random_model(0xA110C);
    let cap = 256usize;
    // min_k 16 dominates frac*len for these context lengths, so the
    // Top-k width — and with it every selection buffer — is constant
    // throughout the run
    let plan = KascadePlan::from_anchors(4, 2, vec![0, 2], TopKRule::new(0.05, 16));
    let mut r = Rng::new(7);
    let prompt_a: Vec<u32> = (0..48).map(|_| r.below(64) as u32).collect();
    let prompt_b: Vec<u32> = (0..40).map(|_| r.below(64) as u32).collect();

    let mut st_a = m.new_state(cap);
    let mut pol_a: Box<dyn SparsePolicy> = Box::new(DensePolicy);
    m.prefill(&prompt_a, &mut st_a, pol_a.as_mut(), None);
    let mut st_b = m.new_state(cap);
    let mut pol_b: Box<dyn SparsePolicy> = Box::new(KascadePolicy::new(plan));
    m.prefill(&prompt_b, &mut st_b, pol_b.as_mut(), None);

    // warm every arena to its steady-state capacity up front
    let (n_q, n_kv) = (m.cfg.n_q_heads, m.cfg.n_kv_heads);
    st_a.scratch.reserve(n_q, n_kv, cap, cap);
    st_b.scratch.reserve(n_q, n_kv, cap, cap);
    let mut scratch = BatchScratch::new();
    scratch.reserve(&m.cfg, 2, cap);

    let mut tok_a = 1u32;
    let mut tok_b = 2u32;
    #[allow(clippy::too_many_arguments)]
    let mut step = |sa: &mut _,
                    pa: &mut Box<dyn SparsePolicy>,
                    sb: &mut _,
                    pb: &mut Box<dyn SparsePolicy>,
                    scr: &mut BatchScratch,
                    ta: &mut u32,
                    tb: &mut u32| {
        let mut reqs = [
            DecodeReq { token: *ta, st: sa, policy: pa.as_mut() },
            DecodeReq { token: *tb, st: sb, policy: pb.as_mut() },
        ];
        m.decode_batch(&mut reqs, scr, None);
        *ta = kascade::tensor::argmax(scr.logits_row(0)) as u32;
        *tb = kascade::tensor::argmax(scr.logits_row(1)) as u32;
    };

    // warmup: policy-internal index buffers and the staging planes reach
    // their steady capacities during these steps
    for _ in 0..12 {
        step(&mut st_a, &mut pol_a, &mut st_b, &mut pol_b, &mut scratch, &mut tok_a, &mut tok_b);
    }

    // The guarantee is that a steady state EXISTS and is reached: some
    // 16-step window must be allocation-free.  Demanding the FIRST
    // window be exactly zero made the test flake on one-off late
    // warm-up (lazy allocator/TLS initialization, a policy buffer that
    // grows once more when the Top-k width settles), which says nothing
    // about the per-token hot loop — so allow a few windows to converge
    // and fail only if none of them is clean.
    let mut last_window = u64::MAX;
    for window in 0..4 {
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..16 {
            step(&mut st_a, &mut pol_a, &mut st_b, &mut pol_b, &mut scratch, &mut tok_a, &mut tok_b);
        }
        last_window = ALLOCS.load(Ordering::SeqCst) - before;
        if last_window == 0 {
            return;
        }
        eprintln!("window {window}: {last_window} allocations, retrying after more warm-up");
    }
    panic!(
        "steady-state decode never reached an allocation-free 16-step window \
         (last window allocated {last_window} times; 2 seqs: dense + kascade)"
    );
}
