//! PJRT-path integration: AOT artifacts load + execute, with numeric
//! parity against the native engine (the cross-layer contract of the
//! three-layer architecture).  Skipped when `make artifacts` has not run.

use kascade::kascade::{calibrate, CalibrateOptions};
use kascade::model::{SynthSpec, VocabLayout};
use kascade::runtime::{PjrtModel, Runtime};
use kascade::sparse::{DensePolicy, KascadePolicy};
use kascade::tensor::argmax;
use kascade::workload::WorkloadGen;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn retrieval_prompt(spec: &SynthSpec, ctx: usize, i: usize, j: usize) -> Vec<u32> {
    let lay = spec.vocab_layout();
    let mut toks = vec![VocabLayout::BOS];
    for f in 0..ctx - 3 {
        toks.push(lay.filler_tok(f * 3 + 1));
    }
    toks[ctx / 3] = lay.pair_tok(i, j);
    toks.push(VocabLayout::QUERY);
    toks.push(lay.key_tok(i));
    toks
}

#[test]
fn manifest_covers_every_op_the_runtime_needs() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let rt = Runtime::load(dir).unwrap();
    let m = &rt.manifest;
    assert!(!m.decode_l.is_empty() && !m.prefill_t.is_empty());
    for l in &m.decode_l {
        for kind in ["dense", "anchor", "anchor0", "reuse"] {
            assert!(m.artifacts.contains_key(&format!("attn_{kind}_decode_l{l}")));
        }
    }
    for t in &m.prefill_t {
        for op in ["embed_prefill", "qkv_prefill", "post_prefill"] {
            assert!(m.artifacts.contains_key(&format!("{op}_t{t}")));
        }
    }
    for op in ["embed_decode", "qkv_decode", "post_decode", "logits_decode"] {
        assert!(m.artifacts.contains_key(op));
    }
}

#[test]
fn pjrt_dense_parity_with_native_engine() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let spec = SynthSpec::pjrt_small(42);
    let native = spec.build();
    let rt = Runtime::load(dir).unwrap();
    let pjrt = PjrtModel::new(rt, &native.w).unwrap();
    let lay = spec.vocab_layout();

    let toks = retrieval_prompt(&spec, 120, 5, 9);
    let mut pst = pjrt.new_state();
    let pl = pjrt.prefill(&toks, &mut pst, None).unwrap();
    let mut nst = native.new_state(toks.len() + 16);
    let (nl, _) = native.prefill(&toks, &mut nst, &mut DensePolicy, None);
    assert_eq!(argmax(&pl), argmax(&nl));
    assert_eq!(argmax(&pl) as u32, lay.value_tok(9));
    let max_diff = pl.iter().zip(&nl).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
    assert!(max_diff < 1e-2, "logit divergence {max_diff}");

    // several decode steps stay in lockstep
    let mut tok = argmax(&pl) as u32;
    for step in 0..3 {
        let p = pjrt.decode_step(tok, &mut pst, None).unwrap();
        let n = native.decode_step(tok, &mut nst, &mut DensePolicy);
        assert_eq!(argmax(&p), argmax(&n), "step {step}");
        tok = argmax(&p) as u32;
    }
}

#[test]
fn pjrt_kascade_plan_path_retrieves_and_reuses() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let spec = SynthSpec::pjrt_small(42);
    let native = spec.build();
    let rt = Runtime::load(dir).unwrap();
    let pjrt = PjrtModel::new(rt, &native.w).unwrap();
    let lay = spec.vocab_layout();

    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let prompts: Vec<Vec<u32>> = (0..2).map(|_| dev.dev_prompt(400)).collect();
    let plan = calibrate(&native, &prompts, &CalibrateOptions::default()).plan;

    let toks = retrieval_prompt(&spec, 400, 11, 30);
    let mut pst = pjrt.new_state();
    let pl = pjrt.prefill(&toks, &mut pst, Some(&plan)).unwrap();
    assert_eq!(argmax(&pl) as u32, lay.value_tok(30), "kascade prefill retrieval");
    // anchor state must be populated for each anchor layer after decode
    let _ = pjrt.decode_step(argmax(&pl) as u32, &mut pst, Some(&plan)).unwrap();
    for &a in &plan.anchors {
        assert!(pst.idx[a].is_some(), "anchor layer {a} never refreshed its indices");
    }
    // parity against the native kascade policy (same plan)
    let mut nst = native.new_state(toks.len() + 16);
    let mut pol = KascadePolicy::new(plan.clone());
    let (nl, _) = native.prefill(&toks, &mut nst, &mut pol, None);
    assert_eq!(argmax(&pl), argmax(&nl), "pjrt vs native kascade answer");
}

#[test]
fn pjrt_bucket_crossing_pads_indices() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let spec = SynthSpec::pjrt_small(42);
    let native = spec.build();
    let rt = Runtime::load(dir).unwrap();
    let first_bucket = rt.manifest.decode_l[0];
    let pjrt = PjrtModel::new(rt, &native.w).unwrap();
    let lay = spec.vocab_layout();

    // prefill just below the first decode bucket, then decode across it
    let toks = retrieval_prompt(&spec, first_bucket - 2, 3, 7);
    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let prompts: Vec<Vec<u32>> = (0..2).map(|_| dev.dev_prompt(400)).collect();
    let plan = calibrate(&native, &prompts, &CalibrateOptions::default()).plan;
    let mut pst = pjrt.new_state();
    let pl = pjrt.prefill(&toks, &mut pst, Some(&plan)).unwrap();
    assert_eq!(argmax(&pl) as u32, lay.value_tok(7));
    let mut tok = argmax(&pl) as u32;
    for _ in 0..4 {
        // crosses from bucket 512 into 1024 without panicking
        let l = pjrt.decode_step(tok, &mut pst, Some(&plan)).unwrap();
        tok = argmax(&l) as u32;
    }
    assert!(pst.len > first_bucket - 2);
}
