//! Tiered KV storage invariants (docs/kv-tiers.md).
//!
//! The load-bearing property is *byte stability*: a hot tile's int8
//! codes must survive any demote -> spill -> promote round-trip exactly,
//! including across copy-on-write forks that share spill records — that
//! is what lets a budget-constrained Kascade decode produce the same
//! token stream as an all-resident run.  Exercised at three levels:
//! the bare `KvCache` (property test), a full `Model::decode_step` loop
//! over a >=128k-token context at a 25% hot budget, and the engine with
//! its tick-boundary prefetch + `ServeMetrics` tier counters.

use kascade::attention::{KvCache, TileTier};
use kascade::config::{KvDtype, ModelConfig, ServeConfig, TopKRule};
use kascade::coordinator::{Completion, NativeBackend, Request, SeqBackend};
use kascade::kascade::KascadePlan;
use kascade::model::{Model, SeqState, Weights};
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::server::{Engine, LocalBackendFactory};
use kascade::sparse::{KascadePolicy, SparsePolicy};
use kascade::tensor::{argmax, Rng};
use kascade::tilestore::{shared_store, MemTileStore, TierParams, TierStats};
use std::sync::Arc;

const N_KV: usize = 2;
const D: usize = 8;
const PS: usize = 16;

fn push_random(c: &mut KvCache, r: &mut Rng, n: usize) {
    let mut k = vec![0.0f32; N_KV * D];
    let mut v = vec![0.0f32; N_KV * D];
    for _ in 0..n {
        r.fill_normal(&mut k, 0.5);
        r.fill_normal(&mut v, 0.5);
        c.push(&k, &v);
    }
}

/// Every completed key row's exact int8 codes + per-tile affine params.
/// Only valid while all completed tiles are hot.
fn snapshot(c: &KvCache, n_pos: usize) -> Vec<(Vec<i8>, f32, f32)> {
    let mut out = Vec::new();
    for h in 0..N_KV {
        for pos in 0..n_pos {
            let (q, s, z) = c.quantized_key_row(h, pos).expect("snapshot of non-hot row");
            out.push((q.to_vec(), s, z));
        }
    }
    out
}

/// Tiering is an int8-only surface: the tier machinery demotes/promotes
/// whole int8 quantization tiles, so the scheduler must reject a tiered
/// config stamped with any other storage mode (f16 and int4 caches keep
/// flat planes and never spill).
#[test]
#[should_panic(expected = "kv_tiers requires kv_dtype=int8")]
fn tiers_reject_non_int8_dtypes() {
    let cfg = ServeConfig {
        kv_tiers: true,
        kv_dtype: KvDtype::F16,
        ..ServeConfig::default()
    };
    let _ = Engine::new(
        cfg,
        Box::new(|_req: &Request| -> Box<dyn SeqBackend> { unreachable!("factory unused") }),
    );
}

#[test]
fn demote_promote_round_trips_hot_tile_bytes() {
    check("tier round-trip is byte-stable", 4, |rng| {
        let store = shared_store(MemTileStore::new());
        let mut c = KvCache::with_tiers(N_KV, D, 256, PS, 0, TierParams::new(4), store);
        let n_tiles = 8usize;
        let n_pos = n_tiles * PS;
        // a few staging rows past the last tile boundary: ensures the
        // tier machinery never touches the f32 staging tail
        push_random(&mut c, rng, n_pos + 5);

        // completions under a 4-tile budget must have demoted LRU tiles
        prop_assert!(c.hot_tiles() <= 4, "budget ignored: {} hot tiles", c.hot_tiles());
        c.ensure_all_hot().map_err(|e| format!("ensure_all_hot: {e}"))?;
        prop_assert!(c.hot_tiles() == n_tiles, "demand promotion may overshoot the budget");
        let before = snapshot(&c, n_pos);

        // demote everything; demoting an already-cold tile is a no-op
        let all: Vec<u32> = (0..n_tiles as u32).collect();
        c.apply_tile_plan(&[], &all).map_err(|e| format!("demote: {e}"))?;
        c.apply_tile_plan(&[], &all).map_err(|e| format!("re-demote: {e}"))?;
        prop_assert!(c.hot_tiles() == 0, "tiles left hot after demote-all");
        for t in 0..n_tiles {
            let tier = c.tile_tier(t);
            prop_assert!(
                tier == Some(TileTier::Warm) || tier == Some(TileTier::Cold),
                "tile {t} reports {tier:?} after demotion"
            );
            prop_assert!(
                c.quantized_key_row(0, t * PS).is_none(),
                "demoted tile {t} still serves quantized rows"
            );
        }

        // warm shadows (int4, diagnostics-only) are tolerance-bounded by
        // the per-tile-head span: |err| <= span/28 per half-step, checked
        // at 2x slack against the dequantized int8 snapshot
        let mut out = vec![0.0f32; D];
        for tile in 0..n_tiles {
            if c.tile_tier(tile) != Some(TileTier::Warm) {
                continue;
            }
            for h in 0..N_KV {
                let rows: Vec<Vec<f32>> = (0..PS)
                    .map(|i| {
                        let (q, s, z) = &before[h * n_pos + tile * PS + i];
                        q.iter().map(|&cc| cc as f32 * s + z).collect()
                    })
                    .collect();
                let lo = rows.iter().flatten().cloned().fold(f32::INFINITY, f32::min);
                let hi = rows.iter().flatten().cloned().fold(f32::NEG_INFINITY, f32::max);
                let tol = (hi - lo) / 14.0 + 1e-4;
                for (i, row) in rows.iter().enumerate() {
                    prop_assert!(
                        c.warm_key_row(h, tile * PS + i, &mut out),
                        "Warm tile {tile} has no shadow row"
                    );
                    for (a, b) in out.iter().zip(row) {
                        prop_assert!(
                            (a - b).abs() <= tol,
                            "warm shadow drifted: {a} vs {b} (tol {tol})"
                        );
                    }
                }
            }
        }

        // promote everything back; promoting a hot tile is a no-op
        c.apply_tile_plan(&all, &[]).map_err(|e| format!("promote: {e}"))?;
        c.apply_tile_plan(&all, &[]).map_err(|e| format!("re-promote: {e}"))?;
        prop_assert!(c.hot_tiles() == n_tiles, "promote-all left tiles cold");
        let after = snapshot(&c, n_pos);
        prop_assert!(before == after, "hot tile bytes changed across demote/promote");
        Ok(())
    });
}

/// A CoW fork shares the parent's spill records for inherited tiles and
/// writes tiles completed after the fork under a fresh owner — a
/// demoted-then-promoted inherited tile is byte-stable on BOTH sides,
/// and post-fork completions never collide in the write-once store.
#[test]
fn fork_shares_spilled_tiles_and_diverges_after() {
    let store = shared_store(MemTileStore::new());
    let mut parent = KvCache::with_tiers(N_KV, D, 128, PS, 3, TierParams::new(2), store);
    let n_tiles = 4usize;
    let n_pos = n_tiles * PS;
    let mut r = Rng::new(0xF02C);
    push_random(&mut parent, &mut r, n_pos);
    parent.ensure_all_hot().unwrap();
    let inherited = snapshot(&parent, n_pos);

    let all: Vec<u32> = (0..n_tiles as u32).collect();
    parent.apply_tile_plan(&[], &all).unwrap();
    assert_eq!(parent.hot_tiles(), 0);

    let mut fork = parent.clone();
    assert!(fork.take_tier_stats().is_zero(), "fork inherited the parent's tier counters");

    // complete one more tile on each side with DIFFERENT rows
    let mut rp = Rng::new(0xAAAA);
    let mut rf = Rng::new(0xBBBB);
    push_random(&mut parent, &mut rp, PS);
    push_random(&mut fork, &mut rf, PS);

    parent.ensure_all_hot().unwrap();
    fork.ensure_all_hot().unwrap();
    assert_eq!(snapshot(&parent, n_pos), inherited, "parent's inherited tiles changed");
    assert_eq!(snapshot(&fork, n_pos), inherited, "fork's inherited tiles changed");

    let prow = parent.quantized_key_row(0, n_pos).unwrap().0.to_vec();
    let frow = fork.quantized_key_row(0, n_pos).unwrap().0.to_vec();
    assert_ne!(prow, frow, "post-fork tiles should hold each side's own rows");

    // the fork's own tile spills under its fresh owner and round-trips
    let t4 = [n_tiles as u32];
    fork.apply_tile_plan(&[], &t4).unwrap();
    assert!(fork.quantized_key_row(0, n_pos).is_none());
    fork.apply_tile_plan(&t4, &[]).unwrap();
    assert_eq!(
        fork.quantized_key_row(0, n_pos).unwrap().0,
        &frow[..],
        "fork's post-fork tile not byte-stable"
    );
    // ... and the parent's divergent tile 4 survives untouched
    assert_eq!(parent.quantized_key_row(0, n_pos).unwrap().0, &prow[..]);
}

fn random_model(seed: u64) -> Model {
    let cfg = ModelConfig {
        n_layers: 4,
        d_model: 32,
        n_q_heads: 4,
        n_kv_heads: N_KV,
        d_head: D,
        d_ff: 64,
        vocab: 64,
        rope_theta: 10000.0,
        rope: true,
    };
    let mut w = Weights::zeros(&cfg);
    let mut r = Rng::new(seed);
    r.fill_normal(&mut w.w_e, 0.3);
    for lw in &mut w.layers {
        r.fill_normal(&mut lw.wq, 0.18);
        r.fill_normal(&mut lw.wk, 0.18);
        r.fill_normal(&mut lw.wv, 0.18);
        r.fill_normal(&mut lw.wo, 0.18);
        r.fill_normal(&mut lw.w1, 0.18);
        r.fill_normal(&mut lw.w3, 0.18);
        r.fill_normal(&mut lw.w2, 0.12);
    }
    r.fill_normal(&mut w.w_u, 0.18);
    Model::new(cfg, w)
}

fn kascade_policy() -> Box<dyn SparsePolicy> {
    Box::new(KascadePolicy::new(KascadePlan::from_anchors(
        4,
        N_KV,
        vec![0, 2],
        TopKRule::new(0.01, 64),
    )))
}

/// Seed every layer cache with the same synthetic K/V rows (prefilling
/// 128k tokens through the full forward pass is O(T^2) — the identity
/// property only needs identical cache CONTENTS, not how they got there).
fn fill_ctx(st: &mut SeqState, t: usize) {
    let mut k = vec![0.0f32; N_KV * D];
    let mut v = vec![0.0f32; N_KV * D];
    for layer in 0..4 {
        let mut r = Rng::new(0x5EED_0000 + layer as u64);
        for _ in 0..t {
            r.fill_normal(&mut k, 0.5);
            r.fill_normal(&mut v, 0.5);
            st.caches[layer].push(&k, &v);
        }
    }
    st.pos = t;
}

/// Kascade decode over a 128Ki-token context with the reuse layers
/// capped at a 25% hot-tile budget must be BITWISE identical to the
/// all-resident int8 run: anchors are tier-exempt (exact selections)
/// and promoted tiles restore exact bytes, so the logits — and the
/// greedy token stream — cannot diverge.
#[test]
fn tiered_kascade_decode_matches_all_resident_128k() {
    const T: usize = 128 * 1024;
    let budget = T / PS / 4; // 25% of the context's completed tiles
    let m = random_model(0x7E12);
    let mut pol_a = kascade_policy();
    let mut pol_b = kascade_policy();
    let store = shared_store(MemTileStore::new());
    let mut st_a = m.new_state_with_dtype(T + 32, KvDtype::Int8);
    let mut st_b = m.new_state_tiered(T + 32, pol_b.as_ref(), TierParams::new(budget), &store);
    fill_ctx(&mut st_a, T);
    fill_ctx(&mut st_b, T);

    // anchor layers 0/2 stay flat; reuse layers 1/3 run tiered and must
    // have spilled down to the budget while the context filled
    assert!(!st_b.caches[0].is_tiered() && !st_b.caches[2].is_tiered());
    for l in [1usize, 3] {
        assert!(st_b.caches[l].is_tiered());
        assert!(
            st_b.caches[l].hot_tiles() <= budget,
            "layer {l}: {} hot tiles over budget {budget}",
            st_b.caches[l].hot_tiles()
        );
    }

    let (mut ta, mut tb) = (1u32, 1u32);
    for step in 0..8 {
        let la = m.decode_step(ta, &mut st_a, pol_a.as_mut());
        let lb = m.decode_step(tb, &mut st_b, pol_b.as_mut());
        assert!(la == lb, "step {step}: tiered logits diverged from all-resident");
        ta = argmax(&la) as u32;
        tb = argmax(&lb) as u32;
        assert_eq!(ta, tb, "step {step}: token streams diverged");
    }

    let mut stats = TierStats::default();
    for c in &mut st_b.caches {
        stats.merge(&c.take_tier_stats());
    }
    assert!(stats.tiles_demoted > 0, "budgeted fill never demoted a tile");
    assert!(stats.tiles_promoted > 0, "sparse decode never promoted a spilled tile");
    assert!(stats.prefetch_hits + stats.prefetch_misses > 0, "policy phase never ensured tiles");
}

fn tier_engine_run(model: Arc<Model>, tiered: bool) -> (Vec<Completion>, Engine) {
    let cap = 512usize;
    let policy = || -> Box<dyn SparsePolicy> {
        Box::new(KascadePolicy::new(KascadePlan::from_anchors(
            4,
            N_KV,
            vec![0, 2],
            TopKRule::new(0.25, 8),
        )))
    };
    let factory: LocalBackendFactory = if tiered {
        let store = shared_store(MemTileStore::new());
        Box::new(move |_req: &Request| {
            Box::new(NativeBackend::with_tiers(
                model.clone(),
                cap,
                policy(),
                TierParams::new(6),
                &store,
            )) as Box<dyn SeqBackend>
        })
    } else {
        Box::new(move |_req: &Request| {
            Box::new(NativeBackend::with_dtype(model.clone(), cap, policy(), KvDtype::Int8))
                as Box<dyn SeqBackend>
        })
    };
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 256,
        max_running: 4,
        token_budget: 128,
        prefill_chunk: 64,
        queue_cap: 16,
        workers: 1,
        enable_prefix_cache: false,
        batched_decode: true,
        kv_dtype: KvDtype::Int8,
        kv_tiers: tiered,
        hot_tile_budget: 6,
        ..ServeConfig::default()
    };
    let mut e = Engine::new(cfg, factory);
    let mut r = Rng::new(0xE1);
    let p1: Vec<u32> = (0..200).map(|_| r.below(64) as u32).collect();
    let p2: Vec<u32> = (0..230).map(|_| r.below(64) as u32).collect();
    let mut handles = vec![
        e.submit(Request::new(p1).max_new(16)).expect("admission rejected request"),
        e.submit(Request::new(p2).max_new(16)).expect("admission rejected request"),
    ];
    let mut done = e.run_to_completion(&mut handles);
    done.sort_by_key(|c| c.id);
    (done, e)
}

/// End-to-end through the engine: tick-boundary tier maintenance feeds
/// the `ServeMetrics` counters, the prefetch actually lands hits, and
/// the tiered token streams match an untiered int8 engine exactly.
#[test]
fn engine_tier_metrics_and_stream_identity() {
    let model = Arc::new(random_model(0xE26E));
    let (tiered, te) = tier_engine_run(model.clone(), true);
    let (flat, _) = tier_engine_run(model, false);
    assert_eq!(tiered.len(), 2);
    assert_eq!(flat.len(), 2);
    for (a, b) in tiered.iter().zip(&flat) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged under tiering", a.id);
    }
    let m = &te.metrics;
    assert!(m.tiles_demoted > 0, "hot budget 6 over ~13 tiles must demote");
    assert!(m.tiles_promoted > 0, "maintenance/demand never promoted");
    assert!(m.prefetch_misses > 0, "a budget smaller than the working set must miss");
    assert!(m.prefetch_hits > 0, "the tick-boundary prefetch never landed a hit");
    let hr = m.prefetch_hit_rate();
    assert!(hr > 0.0 && hr < 1.0, "hit rate {hr} out of range");
}
