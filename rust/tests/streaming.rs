//! Streaming-session API end-to-end properties:
//!
//! * streamed-token identity — the concatenated `Event::Token`s a handle
//!   observes are bitwise-equal to `Completion::tokens`, including
//!   across recompute preemption and prefix-cache resume;
//! * cancellation hygiene — `cancel()` at a random phase (waiting,
//!   mid-prefill, mid-decode) leaves the block pool clean
//!   (`check_invariants`, `used() == 0` after drain) and the snapshot
//!   store orphan-free;
//! * seeded sampling — batched and sequential decode emit identical
//!   streams under `SamplingParams::Seeded`, and preemption replays pick
//!   identical tokens;
//! * the multi-worker `Server` streams, cancels and survives dead
//!   workers through the same typed surface.

use kascade::config::{ModelConfig, SamplingParams, ServeConfig};
use kascade::coordinator::{
    Completion, Event, FailReason, NativeBackend, Request, RequestHandle, SeqBackend,
};
use kascade::model::{Model, Weights};
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::server::{BackendFactory, Engine, Server};
use kascade::tensor::Rng;
use std::sync::Arc;
use std::time::Duration;

/// Wall-clock bound on blocking `Server` waits.  This is purely an
/// anti-hang backstop — no test in this file asserts a latency — so it
/// is sized for heavily oversubscribed CI runners, where a 30s bound
/// has flaked under machine load without indicating any engine bug.
const WAIT: Duration = Duration::from_secs(120);

/// Thread-matrix hook: CI re-runs this suite with `KASCADE_TEST_THREADS=4`
/// so every streaming property also holds on the parallel tick.
fn test_threads() -> usize {
    std::env::var("KASCADE_TEST_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

/// Deterministic backend whose logits depend only on every token it has
/// consumed — recompute after preemption or prefix-cache resume must
/// reproduce the continuation exactly.
struct EchoBackend {
    seen: Vec<u32>,
    vocab: usize,
}

impl EchoBackend {
    fn new(vocab: usize) -> Self {
        Self { seen: Vec::new(), vocab }
    }

    fn logits(&self) -> Vec<f32> {
        let mut h = 0xABCD_EF01_2345_6789u64;
        for &t in &self.seen {
            h = h.wrapping_add(t as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
            h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h ^= h >> 31;
        }
        let mut l = vec![0.0; self.vocab];
        l[(h % self.vocab as u64) as usize] = 1.0;
        l
    }
}

impl SeqBackend for EchoBackend {
    fn prefill_chunk(&mut self, tokens: &[u32], _last: bool) -> Option<Vec<f32>> {
        self.seen.extend_from_slice(tokens);
        Some(self.logits())
    }

    fn decode(&mut self, token: u32) -> Vec<f32> {
        self.seen.push(token);
        self.logits()
    }

    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        if tokens > self.seen.len() {
            return None;
        }
        Some(Box::new(EchoBackend { seen: self.seen[..tokens].to_vec(), vocab: self.vocab }))
    }
}

/// Drain every pending event from `handles` into per-request streams,
/// returning the Done completions encountered.
fn drain(
    handles: &mut [RequestHandle],
    starts: &mut [usize],
    streams: &mut [Vec<u32>],
) -> Vec<Completion> {
    let mut done = Vec::new();
    for (i, h) in handles.iter_mut().enumerate() {
        while let Some(ev) = h.try_next() {
            match ev {
                Event::Started => starts[i] += 1,
                Event::Token { pos, tok } => {
                    assert_eq!(pos, streams[i].len(), "req {i}: non-contiguous token pos");
                    streams[i].push(tok);
                }
                Event::Done(c) => done.push(c),
                Event::Failed(f) => panic!("req {i} failed: {f:?}"),
            }
        }
    }
    done
}

/// Streamed-token identity under forced preemption + prefix-cache
/// resume: 8 requests on an 8-block pool (two concurrent decoders need
/// 10+), half sharing a 32-token prefix.
#[test]
fn streamed_tokens_equal_completion_across_preemption_and_resume() {
    let mut rng = Rng::new(42);
    let shared: Vec<u32> = (0..32).map(|_| rng.below(32) as u32).collect();
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 8,
        max_running: 8,
        token_budget: 128,
        prefill_chunk: 32,
        queue_cap: 64,
        workers: 1,
        num_threads: test_threads(),
        enable_prefix_cache: true,
        prefix_cache_blocks: 4,
        ..ServeConfig::default()
    };
    let mut e = Engine::new(
        cfg,
        Box::new(|_req: &Request| Box::new(EchoBackend::new(32)) as Box<dyn SeqBackend>),
    );
    let reqs: Vec<Request> = (0..8u64)
        .map(|id| {
            let len = 48 + 16 * rng.below(2);
            let mut prompt = if id % 2 == 0 { shared.clone() } else { Vec::new() };
            while prompt.len() < len {
                prompt.push(rng.below(32) as u32);
            }
            Request::new(prompt).max_new(20)
        })
        .collect();
    let mut handles = Vec::new();
    let mut starts = vec![0usize; reqs.len()];
    let mut streams: Vec<Vec<u32>> = vec![Vec::new(); reqs.len()];
    let mut completions = Vec::new();
    let mut guard = 0;
    // serve the first request alone so its shared prefix is registered
    // (and still cached) before the followers contend for it — the
    // followers then interleave prefix resume with forced preemption
    handles.push(e.submit(reqs[0].clone()).expect("admission"));
    while !e.idle() {
        let n = e.tick();
        guard = if n == 0 { guard + 1 } else { 0 };
        assert!(guard < 1000, "livelock");
        completions.extend(drain(&mut handles, &mut starts, &mut streams));
    }
    for r in &reqs[1..] {
        handles.push(e.submit(r.clone()).expect("admission"));
    }
    while !e.idle() {
        let n = e.tick();
        guard = if n == 0 { guard + 1 } else { 0 };
        assert!(guard < 1000, "livelock");
        completions.extend(drain(&mut handles, &mut starts, &mut streams));
    }
    assert_eq!(completions.len(), 8);
    assert!(e.metrics.preemptions > 0, "scenario must actually preempt");
    assert!(e.metrics.prefix_hits > 0, "shared prefixes must actually resume");
    for c in &completions {
        let i = c.id as usize;
        assert_eq!(c.tokens.len(), 20);
        assert_eq!(
            streams[i], c.tokens,
            "req {i}: streamed tokens diverge from the completion"
        );
        assert_eq!(starts[i], 1, "req {i}: exactly one Started, even across preemption");
        assert!(c.ttft_ms.is_some() && c.total_ms.is_some());
    }
    e.sched.blocks.check_invariants().unwrap();
    assert_eq!(e.sched.blocks.used(), 0);
}

/// Cancellation at random phases: every cancelled request reports
/// `Failed(Cancelled)` with its partial tokens; survivors complete; the
/// pool ends clean and the snapshot store holds no orphans.
#[test]
fn cancellation_at_random_phases_keeps_the_pool_clean() {
    check("cancel hygiene", 12, |rng| {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks: 12 + rng.below(32),
            max_running: 1 + rng.below(5),
            token_budget: 32 + rng.below(128),
            prefill_chunk: 8 + rng.below(48),
            queue_cap: 64,
            workers: 1,
            num_threads: test_threads(),
            enable_prefix_cache: true,
            prefix_cache_blocks: 4 + rng.below(16),
            ..ServeConfig::default()
        };
        let mut e = Engine::new(
            cfg,
            Box::new(|_req: &Request| Box::new(EchoBackend::new(32)) as Box<dyn SeqBackend>),
        );
        let shared: Vec<u32> = (0..32).map(|_| rng.below(32) as u32).collect();
        let n = 6;
        let mut handles = Vec::new();
        let mut cancel_at: Vec<Option<usize>> = Vec::new();
        for i in 0..n {
            let mut prompt = if i % 2 == 0 { shared.clone() } else { Vec::new() };
            let len = 17 + rng.below(64);
            while prompt.len() < len {
                prompt.push(rng.below(32) as u32);
            }
            handles.push(
                e.submit(Request::new(prompt).max_new(1 + rng.below(16)))
                    .map_err(|err| format!("admission: {err}"))?,
            );
            // phases: tick 0 = still waiting, later = mid-prefill/decode
            cancel_at.push(if rng.below(2) == 0 { Some(rng.below(12)) } else { None });
        }
        let mut tick = 0usize;
        let mut guard = 0usize;
        while !e.idle() {
            for (i, at) in cancel_at.iter().enumerate() {
                if *at == Some(tick) {
                    handles[i].cancel();
                }
            }
            let did = e.tick();
            e.sched
                .blocks
                .check_invariants()
                .map_err(|err| format!("tick {tick}: {err}"))?;
            guard = if did == 0 { guard + 1 } else { 0 };
            prop_assert!(guard < 1000, "livelock with cancellations");
            tick += 1;
        }
        let mut done = 0;
        let mut failed = 0;
        for h in &mut handles {
            let mut streamed = Vec::new();
            loop {
                match h.try_next() {
                    Some(Event::Token { tok, .. }) => streamed.push(tok),
                    Some(Event::Done(c)) => {
                        done += 1;
                        prop_assert!(c.tokens == streamed, "done diverges from stream");
                        break;
                    }
                    Some(Event::Failed(FailReason::Cancelled(p))) => {
                        failed += 1;
                        prop_assert!(p.tokens == streamed, "partial diverges from stream");
                        prop_assert!(
                            p.ttft_ms.is_some() == !p.tokens.is_empty(),
                            "ttft must be Some iff a token was emitted"
                        );
                        break;
                    }
                    Some(Event::Failed(f)) => return Err(format!("unexpected failure {f:?}")),
                    Some(_) => {}
                    None => return Err("handle ended without a terminal event".into()),
                }
            }
        }
        prop_assert!(done + failed == n, "terminal events lost: {done} + {failed} != {n}");
        prop_assert!(failed as u64 == e.metrics.cancelled, "cancelled metric drifted");
        prop_assert!(
            e.sched.blocks.used() == 0,
            "{} blocks leaked after drain",
            e.sched.blocks.used()
        );
        e.tick(); // drain pending invalidations, then audit the snapshots
        e.check_snapshot_invariants()?;
        Ok(())
    });
}

fn random_model(seed: u64) -> Model {
    let cfg = ModelConfig {
        n_layers: 4,
        d_model: 32,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        d_ff: 64,
        vocab: 64,
        rope_theta: 10000.0,
        rope: true,
    };
    let mut w = Weights::zeros(&cfg);
    let mut r = Rng::new(seed);
    r.fill_normal(&mut w.w_e, 0.3);
    for lw in &mut w.layers {
        r.fill_normal(&mut lw.wq, 0.18);
        r.fill_normal(&mut lw.wk, 0.18);
        r.fill_normal(&mut lw.wv, 0.18);
        r.fill_normal(&mut lw.wo, 0.18);
        r.fill_normal(&mut lw.w1, 0.18);
        r.fill_normal(&mut lw.w3, 0.18);
        r.fill_normal(&mut lw.w2, 0.12);
    }
    r.fill_normal(&mut w.w_u, 0.18);
    Model::new(cfg, w)
}

/// Seeded sampling is an engine-level contract: the step-batched and
/// sequential decode paths see bitwise-equal logits, and the sampler is
/// keyed by `(seed, position)` — so full token streams must agree.
#[test]
fn seeded_sampling_identical_across_batched_and_sequential() {
    let model = Arc::new(random_model(0x5EED));
    let run = |batched: bool| -> Vec<Completion> {
        let cfg = ServeConfig {
            block_size: 8,
            num_blocks: 256,
            max_running: 6,
            token_budget: 128,
            prefill_chunk: 32,
            queue_cap: 16,
            workers: 1,
            num_threads: test_threads(),
            batched_decode: batched,
            ..ServeConfig::default()
        };
        let model = model.clone();
        let mut e = Engine::new(
            cfg,
            Box::new(move |_req: &Request| {
                Box::new(NativeBackend::new(
                    model.clone(),
                    128,
                    Box::new(kascade::sparse::DensePolicy),
                )) as Box<dyn SeqBackend>
            }),
        );
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let prompt: Vec<u32> = (0..16 + i).map(|j| ((j * 11 + i) % 64) as u32).collect();
            handles.push(
                e.submit(
                    Request::new(prompt).max_new(12).sampling(
                        SamplingParams::seeded(1000 + i).temperature(1.3).top_k(16).top_p(0.95),
                    ),
                )
                .expect("admission"),
            );
        }
        let mut done = e.run_to_completion(&mut handles);
        done.sort_by_key(|c| c.id);
        done
    };
    let seq = run(false);
    let bat = run(true);
    assert_eq!(seq.len(), 6);
    for (a, b) in seq.iter().zip(&bat) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {}: seeded streams diverged under batching", a.id);
    }
    // and the same seed replays across an independent engine run
    assert_eq!(run(true)[0].tokens, bat[0].tokens);
}

/// Seeded sampling across recompute preemption: a tight pool forces
/// preemption mid-decode; the replayed sequence must emit the same
/// stream an unpressured run does (the sampler is position-keyed, so
/// folded tokens are not re-drawn).
#[test]
fn seeded_sampling_survives_preemption() {
    let run = |num_blocks: usize| -> (Vec<Completion>, u64) {
        let cfg = ServeConfig {
            block_size: 16,
            num_blocks,
            max_running: 8,
            token_budget: 128,
            prefill_chunk: 32,
            queue_cap: 64,
            workers: 1,
            num_threads: test_threads(),
            ..ServeConfig::default()
        };
        let mut e = Engine::new(
            cfg,
            Box::new(|_req: &Request| Box::new(EchoBackend::new(32)) as Box<dyn SeqBackend>),
        );
        let mut rng = Rng::new(7);
        let mut handles = Vec::new();
        for i in 0..6u64 {
            let prompt: Vec<u32> = (0..48).map(|_| rng.below(32) as u32).collect();
            handles.push(
                e.submit(
                    Request::new(prompt)
                        .max_new(20)
                        .sampling(SamplingParams::seeded(i).temperature(2.0)),
                )
                .expect("admission"),
            );
        }
        let mut done = e.run_to_completion(&mut handles);
        done.sort_by_key(|c| c.id);
        e.sched.blocks.check_invariants().unwrap();
        (done, e.metrics.preemptions)
    };
    let (roomy, p0) = run(256);
    let (tight, p1) = run(8);
    assert_eq!(p0, 0, "roomy run must be unpressured");
    assert!(p1 > 0, "tight run must actually preempt");
    assert_eq!(roomy.len(), 6);
    for (a, b) in roomy.iter().zip(&tight) {
        assert_eq!(a.id, b.id);
        assert_eq!(
            a.tokens, b.tokens,
            "req {}: seeded stream changed under preemption",
            a.id
        );
    }
}

/// Priority jumps the admission queue: with one running slot, a
/// high-priority request submitted second still starts (and finishes)
/// first.
#[test]
fn priority_request_starts_first() {
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 64,
        max_running: 1,
        token_budget: 64,
        prefill_chunk: 64,
        queue_cap: 8,
        workers: 1,
        num_threads: test_threads(),
        ..ServeConfig::default()
    };
    let mut e = Engine::new(
        cfg,
        Box::new(|_req: &Request| Box::new(EchoBackend::new(32)) as Box<dyn SeqBackend>),
    );
    let mut low = e.submit(Request::new(vec![1; 32]).max_new(2)).unwrap();
    let mut high = e
        .submit(Request::new(vec![2; 32]).max_new(2).priority(10))
        .unwrap();
    e.tick();
    assert!(
        matches!(high.try_next(), Some(Event::Started)),
        "high priority must be admitted on the first tick"
    );
    assert!(
        !matches!(low.try_next(), Some(Event::Started)),
        "the single running slot belongs to the high-priority request"
    );
    let mut handles = [low, high];
    let done = e.run_to_completion(&mut handles);
    assert_eq!(done.len(), 2, "both eventually complete");
}

fn echo_factory() -> BackendFactory {
    Box::new(|_req| Box::new(EchoBackend::new(32)))
}

/// The Server streams the same events across threads: tokens arrive
/// while the request runs, cancel() tears a live session down, and the
/// partial completion matches what was streamed.
#[test]
fn server_streams_tokens_and_cancels_mid_flight() {
    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 256,
        max_running: 4,
        token_budget: 64,
        prefill_chunk: 32,
        queue_cap: 32,
        workers: 1,
        num_threads: test_threads(),
        ..ServeConfig::default()
    };
    let mut srv = Server::start(cfg, vec![echo_factory(), echo_factory()]);
    // a finite request, streamed to completion
    let mut h = srv
        .submit(Request::new(vec![3; 40]).max_new(8), Some(1))
        .unwrap();
    let mut streamed = Vec::new();
    let done = loop {
        match h.next_timeout(WAIT) {
            Some(Event::Token { tok, .. }) => streamed.push(tok),
            Some(Event::Done(c)) => break c,
            Some(Event::Failed(f)) => panic!("unexpected failure: {f:?}"),
            Some(_) => {}
            None => panic!("timed out waiting for events"),
        }
    };
    assert_eq!(done.tokens.len(), 8);
    assert_eq!(done.tokens, streamed, "server-streamed tokens reassemble the completion");
    // an effectively-unbounded request, cancelled mid-stream
    let mut h = srv
        .submit(Request::new(vec![4; 40]).max_new(1_000_000), Some(2))
        .unwrap();
    // wait until it demonstrably streams, then cancel
    let first = h.next_timeout(WAIT);
    assert!(first.is_some(), "request never started streaming");
    h.cancel();
    match h.wait(WAIT) {
        Err(FailReason::Cancelled(partial)) => {
            assert!(partial.total_ms.is_some());
        }
        other => panic!("expected Cancelled, got {other:?}"),
    }
    let metrics = srv.shutdown();
    let cancelled: u64 = metrics.iter().map(|m| m.cancelled).sum();
    assert_eq!(cancelled, 1);
}
