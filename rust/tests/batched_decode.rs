//! Step-batched decode equivalence: an engine executing each tick's
//! decodes as one layer-major batched forward must produce exactly the
//! same token streams as the sequential path, across random batch sizes,
//! policies (dense / Kascade), mid-stream sequence completion and
//! admission, and prefix-cache-fork resumed sequences joining a live
//! batch.  (Bitwise logits equality of `Model::decode_batch` itself is
//! unit-tested next to the forward pass; this exercises the whole
//! scheduler -> engine -> backend stack.)

use kascade::config::{ModelConfig, ServeConfig, TopKRule};
use kascade::coordinator::{Completion, Event, NativeBackend, Request, RequestHandle};
use kascade::kascade::KascadePlan;
use kascade::model::{Model, Weights};
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::server::{Engine, LocalBackendFactory};
use kascade::sparse::{DensePolicy, KascadePolicy, SparsePolicy};
use kascade::tensor::Rng;
use std::sync::Arc;

const VOCAB: usize = 64;

/// Thread-matrix hook: CI re-runs this suite with `KASCADE_TEST_THREADS=4`
/// so every batched==sequential property also holds on the parallel tick.
fn test_threads() -> usize {
    std::env::var("KASCADE_TEST_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}

fn random_model(seed: u64) -> Model {
    let cfg = ModelConfig {
        n_layers: 4,
        d_model: 32,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        d_ff: 64,
        vocab: VOCAB,
        rope_theta: 10000.0,
        rope: true,
    };
    let mut w = Weights::zeros(&cfg);
    let mut r = Rng::new(seed);
    r.fill_normal(&mut w.w_e, 0.3);
    for lw in &mut w.layers {
        r.fill_normal(&mut lw.wq, 0.18);
        r.fill_normal(&mut lw.wk, 0.18);
        r.fill_normal(&mut lw.wv, 0.18);
        r.fill_normal(&mut lw.wo, 0.18);
        r.fill_normal(&mut lw.w1, 0.18);
        r.fill_normal(&mut lw.w3, 0.18);
        r.fill_normal(&mut lw.w2, 0.12);
    }
    r.fill_normal(&mut w.w_u, 0.18);
    Model::new(cfg, w)
}

fn factory(model: Arc<Model>, cap: usize, kascade: bool) -> LocalBackendFactory {
    Box::new(move |_req| {
        let policy: Box<dyn SparsePolicy> = if kascade {
            Box::new(KascadePolicy::new(KascadePlan::from_anchors(
                4,
                2,
                vec![0, 2],
                TopKRule::new(0.25, 8),
            )))
        } else {
            Box::new(DensePolicy)
        };
        Box::new(NativeBackend::new(model.clone(), cap, policy))
    })
}

/// Run `arrivals` (request, submit-at-tick) to completion and return the
/// per-request completions (sorted by id) plus the engine for metric
/// inspection.
fn run(
    arrivals: &[(Request, usize)],
    batched: bool,
    kascade: bool,
    model: Arc<Model>,
    cap: usize,
) -> (Vec<Completion>, Engine) {
    let cfg = ServeConfig {
        block_size: 8,
        num_blocks: 512,
        max_running: 8,
        token_budget: 128,
        prefill_chunk: 32,
        queue_cap: 64,
        workers: 1,
        enable_prefix_cache: true,
        prefix_cache_blocks: 128,
        batched_decode: batched,
        num_threads: test_threads(),
        ..ServeConfig::default()
    };
    let mut e = Engine::new(cfg, factory(model, cap, kascade));
    let mut tick = 0usize;
    let mut submitted = 0usize;
    let mut guard = 0usize;
    let mut handles: Vec<RequestHandle> = Vec::new();
    let mut done: Vec<Completion> = Vec::new();
    loop {
        for (req, at) in arrivals {
            if *at == tick {
                // submission order fixes the engine-assigned ids, so the
                // batched and sequential runs stay comparable by id
                handles.push(e.submit(req.clone()).expect("admission rejected request"));
                submitted += 1;
            }
        }
        if submitted == arrivals.len() && e.idle() {
            break;
        }
        let did = e.tick();
        guard = if did == 0 { guard + 1 } else { 0 };
        assert!(guard < 1000, "engine livelock");
        for h in &mut handles {
            while let Some(ev) = h.try_next() {
                if let Event::Done(c) = ev {
                    done.push(c);
                }
            }
        }
        tick += 1;
    }
    done.sort_by_key(|c| c.id);
    (done, e)
}

#[test]
fn batched_decode_streams_equal_sequential_property() {
    let model = Arc::new(random_model(0xBA7C4));
    check("batched == sequential decode", 6, |rng| {
        let kascade = rng.below(2) == 0;
        let n_reqs = 3 + rng.below(6); // up to 8 concurrent decoders
        // a shared document prefix so later arrivals resume from
        // prefix-cache forks and join the live decode batch
        let shared_len = 16 + 8 * rng.below(4);
        let shared: Vec<u32> = (0..shared_len).map(|_| rng.below(VOCAB) as u32).collect();
        let mut arrivals = Vec::new();
        let mut cap = 0usize;
        for id in 0..n_reqs {
            let mut prompt = if rng.below(3) > 0 {
                shared.clone() // prefix-cache candidates
            } else {
                (0..8 + rng.below(24)).map(|_| rng.below(VOCAB) as u32).collect()
            };
            for _ in 0..rng.below(12) {
                prompt.push(rng.below(VOCAB) as u32);
            }
            // mid-stream completion; request 0 always decodes several
            // tokens so at least one step-batched forward pass happens
            let max_new = if id == 0 { 4 + rng.below(9) } else { 1 + rng.below(12) };
            cap = cap.max(prompt.len() + max_new + 8);
            let at = rng.below(6); // staggered admission joins live batches
            arrivals.push((Request::new(prompt).max_new(max_new), at));
        }
        let (seq, _) = run(&arrivals, false, kascade, model.clone(), cap);
        let (bat, eng) = run(&arrivals, true, kascade, model.clone(), cap);
        prop_assert!(seq.len() == arrivals.len(), "sequential lost requests");
        prop_assert!(bat.len() == arrivals.len(), "batched lost requests");
        prop_assert!(
            eng.metrics.decode_batch.count() > 0,
            "batched run never took the step-batched path"
        );
        for (a, b) in seq.iter().zip(&bat) {
            prop_assert!(a.id == b.id, "id mismatch {} vs {}", a.id, b.id);
            prop_assert!(
                a.tokens == b.tokens,
                "req {} diverged: sequential {:?} vs batched {:?} (kascade={kascade})",
                a.id,
                a.tokens,
                b.tokens
            );
        }
        Ok(())
    });
}

/// A prefix-cache resume mid-stream must not perturb batched decode: the
/// follower forks the leader's snapshot, finishes its short prefill, and
/// joins the live decode batch.  Batched and sequential execution of the
/// exact same arrival schedule (caching held constant — a Kascade resume
/// legitimately re-tiles prefill vs. an uncached run) must agree exactly.
#[test]
fn prefix_fork_joins_live_batch_unperturbed() {
    let model = Arc::new(random_model(0xF0F0));
    let shared: Vec<u32> = (0..40).map(|i| (i * 7 % VOCAB) as u32).collect();
    let mut leader_prompt = shared.clone();
    leader_prompt.extend([3u32, 9, 27]);
    let mut follower_prompt = shared;
    follower_prompt.extend([5u32, 25]);
    let arrivals = vec![
        (Request::new(leader_prompt).max_new(24), 0usize),
        // arrives while the leader is mid-decode
        (Request::new(follower_prompt).max_new(8), 8usize),
    ];
    let (bat, bat_eng) = run(&arrivals, true, true, model.clone(), 128);
    let (seq, seq_eng) = run(&arrivals, false, true, model, 128);
    assert_eq!(bat.len(), 2);
    assert_eq!(seq.len(), 2);
    assert!(
        bat_eng.metrics.prefix_hits > 0 && seq_eng.metrics.prefix_hits > 0,
        "follower must resume from the leader's prefix snapshot in both runs"
    );
    assert!(
        bat_eng.metrics.decode_batch.percentile(100.0) >= 2.0,
        "leader and follower must actually decode together in one batch"
    );
    for (a, b) in bat.iter().zip(&seq) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged under batching", a.id);
    }
}
