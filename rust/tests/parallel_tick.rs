//! Deterministic parallel tick: an engine running its decode work on a
//! worker pool (`ServeConfig::num_threads` > 1) must emit **bitwise
//! identical** token streams to the serial engine, across random
//! policies (dense / Kascade / Quest), preemption under block pressure,
//! staggered mid-stream admission, prefix-cache resumes, and mid-stream
//! cancellation.  Every parallel work item is self-contained (own
//! softmax, disjoint output rows) and shared accounting folds back in
//! fixed order — this suite fuzzes that invariant end to end.

use kascade::config::{ModelConfig, ServeConfig, TopKRule};
use kascade::coordinator::{Completion, Event, NativeBackend, Request, RequestHandle};
use kascade::kascade::KascadePlan;
use kascade::model::{Model, Weights};
use kascade::prop_assert;
use kascade::proptest_lite::check;
use kascade::server::{Engine, LocalBackendFactory};
use kascade::sparse::{DensePolicy, KascadePolicy, QuestPolicy, SparsePolicy};
use kascade::tensor::Rng;
use std::sync::Arc;

const VOCAB: usize = 64;

fn random_model(seed: u64) -> Model {
    let cfg = ModelConfig {
        n_layers: 4,
        d_model: 32,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        d_ff: 64,
        vocab: VOCAB,
        rope_theta: 10000.0,
        rope: true,
    };
    let mut w = Weights::zeros(&cfg);
    let mut r = Rng::new(seed);
    r.fill_normal(&mut w.w_e, 0.3);
    for lw in &mut w.layers {
        r.fill_normal(&mut lw.wq, 0.18);
        r.fill_normal(&mut lw.wk, 0.18);
        r.fill_normal(&mut lw.wv, 0.18);
        r.fill_normal(&mut lw.wo, 0.18);
        r.fill_normal(&mut lw.w1, 0.18);
        r.fill_normal(&mut lw.w3, 0.18);
        r.fill_normal(&mut lw.w2, 0.12);
    }
    r.fill_normal(&mut w.w_u, 0.18);
    Model::new(cfg, w)
}

/// Policy varies by prompt length so runs at different thread counts
/// still build identical policies per request.
fn factory(model: Arc<Model>, cap: usize) -> LocalBackendFactory {
    Box::new(move |req| {
        let policy: Box<dyn SparsePolicy> = match req.prompt.len() % 3 {
            0 => Box::new(DensePolicy),
            1 => Box::new(KascadePolicy::new(KascadePlan::from_anchors(
                4,
                2,
                vec![0, 2],
                TopKRule::new(0.25, 8),
            ))),
            _ => Box::new(QuestPolicy::new(TopKRule::new(0.25, 8))),
        };
        Box::new(NativeBackend::new(model.clone(), cap, policy))
    })
}

/// Run an arrival schedule on one engine config; returns completions
/// (sorted by id) plus the cancelled ids' partial streams.
#[allow(clippy::type_complexity)]
fn run(
    arrivals: &[(Request, usize)],
    cancel_at: &[(usize, usize)], // (request index, cancel tick)
    num_threads: usize,
    tight_blocks: bool,
    model: Arc<Model>,
    cap: usize,
) -> (Vec<Completion>, Vec<(u64, Vec<u32>)>) {
    let cfg = ServeConfig {
        block_size: 8,
        num_blocks: if tight_blocks { 96 } else { 512 },
        max_running: 8,
        token_budget: 128,
        prefill_chunk: 32,
        queue_cap: 64,
        workers: 1,
        enable_prefix_cache: true,
        prefix_cache_blocks: 64,
        batched_decode: true,
        num_threads,
        ..ServeConfig::default()
    };
    let mut e = Engine::new(cfg, factory(model, cap));
    let mut tick = 0usize;
    let mut submitted = 0usize;
    let mut guard = 0usize;
    let mut handles: Vec<RequestHandle> = Vec::new();
    let mut done: Vec<Completion> = Vec::new();
    let mut failed: Vec<(u64, Vec<u32>)> = Vec::new();
    loop {
        for (req, at) in arrivals {
            if *at == tick {
                handles.push(e.submit(req.clone()).expect("admission rejected request"));
                submitted += 1;
            }
        }
        for &(ri, at) in cancel_at {
            if at == tick && ri < handles.len() {
                handles[ri].cancel();
            }
        }
        if submitted == arrivals.len() && e.idle() {
            break;
        }
        let did = e.tick();
        // livelock guard counts consecutive ZERO-WORK ticks, never wall
        // time — deterministic even when the worker pool is scheduled
        // erratically on an oversubscribed CI runner
        guard = if did == 0 { guard + 1 } else { 0 };
        assert!(guard < 1000, "engine livelock");
        for h in &mut handles {
            while let Some(ev) = h.try_next() {
                match ev {
                    Event::Done(c) => done.push(c),
                    Event::Failed(kascade::coordinator::FailReason::Cancelled(c)) => {
                        failed.push((c.id, c.tokens))
                    }
                    _ => {}
                }
            }
        }
        tick += 1;
    }
    done.sort_by_key(|c| c.id);
    failed.sort_by_key(|&(id, _)| id);
    (done, failed)
}

#[test]
fn thread_counts_emit_bitwise_identical_streams() {
    let model = Arc::new(random_model(0x7E4D));
    check("num_threads stream identity", 5, |rng| {
        let tight_blocks = rng.below(2) == 0;
        let n_reqs = 3 + rng.below(5);
        let shared_len = 3 * (8 + 4 * rng.below(3)); // multiple of 3 -> dense leader
        let shared: Vec<u32> = (0..shared_len).map(|_| rng.below(VOCAB) as u32).collect();
        let mut arrivals = Vec::new();
        let mut cap = 0usize;
        for i in 0..n_reqs {
            let mut prompt = if rng.below(3) > 0 {
                shared.clone()
            } else {
                (0..9 + rng.below(24)).map(|_| rng.below(VOCAB) as u32).collect()
            };
            for _ in 0..rng.below(9) {
                prompt.push(rng.below(VOCAB) as u32);
            }
            let max_new = if i == 0 { 6 + rng.below(10) } else { 1 + rng.below(12) };
            cap = cap.max(prompt.len() + max_new + 16);
            let at = rng.below(6);
            arrivals.push((Request::new(prompt).max_new(max_new), at));
        }
        // one deterministic mid-stream cancellation per case
        let cancels = vec![(rng.below(n_reqs), 3 + rng.below(4))];
        let (base_done, base_failed) =
            run(&arrivals, &cancels, 1, tight_blocks, model.clone(), cap);
        for threads in [2usize, 4] {
            let (done, failed) =
                run(&arrivals, &cancels, threads, tight_blocks, model.clone(), cap);
            prop_assert!(
                done.len() == base_done.len(),
                "threads={threads}: {} vs {} completions",
                done.len(),
                base_done.len()
            );
            for (a, b) in base_done.iter().zip(&done) {
                prop_assert!(a.id == b.id, "threads={threads}: id {} vs {}", a.id, b.id);
                prop_assert!(
                    a.tokens == b.tokens,
                    "threads={threads}: req {} diverged: {:?} vs {:?}",
                    a.id,
                    a.tokens,
                    b.tokens
                );
            }
            prop_assert!(
                base_failed == failed,
                "threads={threads}: cancelled partials diverged"
            );
        }
        Ok(())
    });
}
