//! Prefix-cache end-to-end properties on the SynthLM backend.
//!
//! * cache on vs. off must produce byte-identical per-request outputs
//!   (dense attention is bit-exact under any chunk split, and a resumed
//!   sequence's snapshot state is the very floats the donor computed);
//! * cache on must do strictly fewer prefill tokens;
//! * Kascade's per-sequence Top-k index state must not leak through
//!   shared KV blocks — resumed sequences rebuild their own.

use kascade::config::{ServeConfig, TopKRule};
use kascade::coordinator::{Completion, NativeBackend, Request, SeqBackend};
use kascade::kascade::KascadePlan;
use kascade::model::{Model, SynthSpec};
use kascade::server::{Engine, LocalBackendFactory};
use kascade::sparse::{DensePolicy, KascadePolicy, SparsePolicy};
use kascade::workload::{grade, Task, WorkloadGen};
use std::cell::Cell;
use std::rc::Rc;
use std::sync::Arc;

fn spec() -> SynthSpec {
    let mut s = SynthSpec::eval_base(11);
    s.cfg.n_layers = 4;
    s.block_starts = vec![1];
    s
}

/// Wraps a backend to count prefilled tokens (compute actually done).
struct Counting {
    inner: Box<dyn SeqBackend>,
    prefilled: Rc<Cell<u64>>,
}

impl SeqBackend for Counting {
    fn prefill_chunk(&mut self, tokens: &[u32], last: bool) -> Option<Vec<f32>> {
        self.prefilled.set(self.prefilled.get() + tokens.len() as u64);
        self.inner.prefill_chunk(tokens, last)
    }

    fn decode(&mut self, token: u32) -> Vec<f32> {
        self.inner.decode(token)
    }

    fn fork_prefix(&self, tokens: usize) -> Option<Box<dyn SeqBackend>> {
        let inner = self.inner.fork_prefix(tokens)?;
        Some(Box::new(Counting { inner, prefilled: self.prefilled.clone() }))
    }
}

fn factory(
    model: Arc<Model>,
    cap: usize,
    counter: Rc<Cell<u64>>,
    plan: Option<KascadePlan>,
) -> LocalBackendFactory {
    Box::new(move |_req| {
        let policy: Box<dyn SparsePolicy> = match &plan {
            Some(p) => Box::new(KascadePolicy::new(p.clone())),
            None => Box::new(DensePolicy),
        };
        Box::new(Counting {
            inner: Box::new(NativeBackend::new(model.clone(), cap, policy)),
            prefilled: counter.clone(),
        })
    })
}

fn cfg(enable: bool) -> ServeConfig {
    ServeConfig {
        block_size: 16,
        num_blocks: 512, // roomy: no preemption noise in these tests
        max_running: 4,
        token_budget: 256,
        prefill_chunk: 128,
        queue_cap: 64,
        workers: 1,
        enable_prefix_cache: enable,
        prefix_cache_blocks: 256,
        batched_decode: true,
        ..ServeConfig::default()
    }
}

/// Serve `tasks` one after another (steady-state RAG shape) and return
/// (completions by id, prefill tokens actually computed, engine).
fn serve(tasks: &[Task], enable: bool, plan: Option<KascadePlan>) -> (Vec<Completion>, u64, Engine) {
    let model = Arc::new(spec().build());
    let cap = tasks.iter().map(|t| t.prompt.len() + t.max_new + 8).max().unwrap();
    let counter = Rc::new(Cell::new(0u64));
    let mut engine = Engine::new(cfg(enable), factory(model, cap, counter.clone(), plan));
    let mut done = Vec::new();
    let mut handles = Vec::new();
    for t in tasks {
        handles.push(
            engine
                .submit(Request::new(t.prompt.clone()).max_new(t.max_new))
                .expect("admission"),
        );
        done.extend(engine.run_to_completion(&mut handles));
    }
    done.sort_by_key(|c| c.id);
    (done, counter.get(), engine)
}

#[test]
fn cache_on_equals_cache_off_with_strictly_fewer_prefill_tokens() {
    let mut gen = WorkloadGen::new(&spec(), 0xA11CE);
    let tasks = gen.rag_suite(4, 400, 48);
    let (off, prefilled_off, off_engine) = serve(&tasks, false, None);
    let (on, prefilled_on, on_engine) = serve(&tasks, true, None);
    assert_eq!(off.len(), 4);
    assert_eq!(on.len(), 4);
    for (a, b) in off.iter().zip(&on) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged with caching on", a.id);
    }
    // dense SynthLM retrieval is exact: the shared-document facts are
    // recovered correctly in both runs
    for (t, c) in tasks.iter().zip(&on) {
        assert!(grade(t, &c.tokens), "request answered incorrectly");
    }
    assert!(
        prefilled_on < prefilled_off,
        "cache on must compute fewer prefill tokens ({prefilled_on} vs {prefilled_off})"
    );
    let m = &on_engine.metrics;
    assert_eq!(m.prefix_hits, 3, "every follower hits");
    assert_eq!(m.prefix_misses, 1, "only the first request misses");
    // deepest resumable boundary below the 400-token shared prefix is
    // the 384-token chunk boundary
    assert_eq!(m.saved_prefill_tokens, 3 * 384);
    assert_eq!(prefilled_off - prefilled_on, 3 * 384);
    assert_eq!(off_engine.metrics.prefix_hits, 0);
    for c in &on[1..] {
        assert_eq!(c.cached_prefix_tokens, 384);
    }
    on_engine.sched.blocks.check_invariants().unwrap();
    assert!(on_engine.sched.blocks.cached() > 0, "prefix blocks retained");
}

#[test]
fn kascade_index_state_stays_per_sequence_across_shared_blocks() {
    // the composition the tentpole must get right: KV blocks are shared
    // through the prefix cache while reuse-layer Top-k state stays
    // per-sequence.  Identical requests resumed from the same snapshot
    // take identical compute paths, so their outputs must agree exactly.
    let s = spec();
    let plan = KascadePlan::from_anchors(
        s.cfg.n_layers,
        s.cfg.n_kv_heads,
        vec![0, 2],
        TopKRule::new(0.2, 48),
    );
    let mut gen = WorkloadGen::new(&s, 0xBEE);
    let t = gen.rag_suite(1, 400, 32).remove(0);
    let plen = t.prompt.len() as u64;
    let tasks = vec![t.clone(), t.clone(), t];
    let (done, prefilled, engine) = serve(&tasks, true, Some(plan));
    assert_eq!(done.len(), 3);
    let m = &engine.metrics;
    assert_eq!(m.prefix_hits, 2);
    assert!(prefilled < 3 * plen, "followers skipped shared prefill");
    assert_eq!(done[1].tokens, done[2].tokens, "identical resumed requests must agree");
    assert_eq!(done[1].cached_prefix_tokens, done[2].cached_prefix_tokens);
    assert!(done[1].cached_prefix_tokens >= 384);
    engine.sched.blocks.check_invariants().unwrap();
}
