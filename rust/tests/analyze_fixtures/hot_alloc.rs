//! Fixture: `hot-path-alloc` rule (tests/analyze.rs).  The marked fn
//! must be flagged, the identical unmarked fn must not.

// analyze: hot-path
pub fn kernel_accumulate(out: &mut Vec<f32>) {
    out.push(1.0); // violation: allocation token in a marked fn
}

pub fn setup_accumulate(out: &mut Vec<f32>) {
    out.push(2.0); // trap: unmarked fns may allocate freely
}
