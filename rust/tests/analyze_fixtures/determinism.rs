//! Fixture: `determinism` rule (tests/analyze.rs).  One planted clock
//! read, one hash-iteration, plus two false-positive traps.

use std::collections::HashMap;
use std::time::Instant;

pub fn tick_clock() -> Instant {
    Instant::now() // violation: ambient clock read
}

pub fn count_all(seqs: HashMap<u64, u32>) -> usize {
    let mut n = 0;
    for (_k, v) in &seqs {
        n += *v as usize;
    }
    n
}

pub fn keyed_lookup(seqs: &HashMap<u64, u32>) -> Option<u32> {
    seqs.get(&1).copied() // trap: keyed access is deterministic
}

#[cfg(test)]
mod tests {
    #[test]
    fn clock_in_tests_is_exempt() {
        let _ = std::time::Instant::now(); // trap: test spans are exempt
    }
}
