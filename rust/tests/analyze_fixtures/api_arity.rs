//! Fixture: `api-surface` call-site rule (tests/analyze.rs).  One
//! arity-mismatched call fires; the correct-arity call stays silent.

pub fn transmogrify(level: u32, gain: u32) -> u32 {
    level + gain
}

pub fn miscall() -> u32 {
    transmogrify(1, 2, 3) // violation: arity mismatch
}

pub fn goodcall() -> u32 {
    transmogrify(4, 5) // trap: correct arity
}
