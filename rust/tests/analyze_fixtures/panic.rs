//! Fixture: `panic-path` rule (tests/analyze.rs).  Unguarded caller
//! index + unwrap fire; a bounds-guarded index and test-span unwraps
//! stay silent.

pub struct Mailbox {
    slots: Vec<u32>,
    pending: Option<u32>,
}

impl Mailbox {
    pub fn slot_of(&self, w: usize) -> u32 {
        self.slots[w] // violation: caller-provided index, no guard
    }

    pub fn take_pending(&mut self) -> u32 {
        self.pending.take().unwrap() // violation: panic on a request path
    }

    pub fn slot_checked(&self, w: usize) -> u32 {
        if w < self.slots.len() {
            self.slots[w] // trap: bounds-guarded
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_in_tests_is_exempt() {
        let mb = Mailbox { slots: vec![7], pending: Some(1) };
        let _ = mb.pending;
        assert_eq!(mb.slots.first().copied().unwrap(), 7); // trap
    }
}
