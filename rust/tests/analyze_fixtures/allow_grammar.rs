//! Fixture: `allow-grammar` rule (tests/analyze.rs).  A reasonless
//! annotation is itself a finding and suppresses nothing; a reasoned
//! one suppresses its target and produces no stale-allow warning.

pub struct Latch {
    armed: Option<u32>,
    primed: Option<u32>,
}

impl Latch {
    pub fn fire_unaudited(&mut self) -> u32 {
        // analyze: allow(panic-path)
        self.armed.take().unwrap() // violations: reasonless allow + live unwrap
    }

    pub fn fire_audited(&mut self) -> u32 {
        // analyze: allow(panic-path) — fixture: audited invariant, primed is always Some
        self.primed.take().unwrap() // trap: a reasoned allow suppresses
    }
}
