//! Offline type-surface stub for the `xla` (xla_extension) bindings.
//!
//! The PJRT runtime path ([`kascade::runtime`]) compiles against this
//! crate; every operation returns an "unavailable" error at runtime.
//! That is safe because the PJRT integration tests and the e2e example
//! gate on `artifacts/manifest.json` existing before touching the
//! runtime — without artifacts they self-skip, and producing artifacts
//! requires the real toolchain anyway.  Swap this path dependency for
//! the real `xla` crate (LaurentMazare/xla-rs, xla_extension 0.5.x) to
//! enable actual artifact execution; no call sites change.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: xla_extension is not available in this build (offline stub; \
         swap rust/vendor/xla for the real bindings)"
    )))
}

/// Element types the stub accepts where the real bindings are generic.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}
