//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the exact API surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the
//! [`Context`] extension trait.  Errors are plain formatted strings —
//! no backtraces, no downcasting.  Replace with the real `anyhow` when a
//! registry is reachable; no call sites need to change.

use std::fmt;

/// String-backed error type (stand-in for `anyhow::Error`).
pub struct Error(String);

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (subset of `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error(c.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_and_context() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        let e: Error = anyhow!(String::from("boxed"));
        assert_eq!(e.to_string(), "boxed");
        let r: Result<()> = Err(anyhow!("inner"));
        let r = r.context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert!(o.with_context(|| "missing").is_err());
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).is_err());
        assert!(f(7).is_err());
    }
}
