//! Long-context serving demo: the full coordinator (continuous batcher,
//! paged KV blocks, chunked prefill, preemption, router) serving a batch
//! of retrieval requests over the native SynthLM engine, dense vs Kascade.
//!
//! Run: `cargo run --release --example serve_longcontext`

use kascade::config::ServeConfig;
use kascade::coordinator::{NativeBackend, Request};
use kascade::kascade::{calibrate, CalibrateOptions, KascadePlan};
use kascade::model::{Model, SynthSpec};
use kascade::server::{BackendFactory, Engine};
use kascade::sparse::{DensePolicy, KascadePolicy, SparsePolicy};
use kascade::workload::{Category, WorkloadGen};
use std::sync::Arc;

const CTX: usize = 1024;
const N_REQUESTS: usize = 12;

fn factory(model: Arc<Model>, plan: Option<KascadePlan>) -> BackendFactory {
    Box::new(move |_req| {
        let policy: Box<dyn SparsePolicy> = match &plan {
            Some(p) => Box::new(KascadePolicy::new(p.clone())),
            None => Box::new(DensePolicy),
        };
        Box::new(NativeBackend::new(model.clone(), CTX + 64, policy))
    })
}

fn main() -> anyhow::Result<()> {
    let spec = SynthSpec::eval_base(42);
    let model = Arc::new(spec.build());
    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let prompts: Vec<Vec<u32>> = (0..3).map(|_| dev.dev_prompt(768)).collect();
    let plan = calibrate(&model, &prompts, &CalibrateOptions::default()).plan;
    println!("calibrated anchors: {:?}\n", plan.anchors);

    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 16384,
        max_running: 8,
        token_budget: 1024,
        prefill_chunk: 256,
        queue_cap: 64,
        workers: 1,
        ..ServeConfig::default()
    };

    for (name, plan) in [("dense", None), ("kascade", Some(plan))] {
        let mut engine = Engine::new(cfg.clone(), factory(model.clone(), plan));
        let mut gen = WorkloadGen::new(&spec, 0x5EED);
        let mut expected = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..N_REQUESTS {
            let t = gen.longbench(Category::Sqa, CTX);
            expected.push(t.expect[0]);
            handles.push(
                engine
                    .submit(
                        Request::new(t.prompt)
                            .max_new(t.max_new)
                            .stop(*t.expect.last().unwrap()),
                    )
                    .expect("admission"),
            );
        }
        // one extra session we tear down mid-stream: cancellation frees
        // its KV blocks within a tick, surfaced in the metrics report
        let bonus = gen.longbench(Category::Sqa, CTX);
        let cancelled = engine
            .submit(Request::new(bonus.prompt).max_new(1_000))
            .expect("admission");
        let t0 = std::time::Instant::now();
        for _ in 0..20 {
            engine.tick();
        }
        cancelled.cancel();
        let done = engine.run_to_completion(&mut handles);
        let wall = t0.elapsed().as_secs_f64();
        let correct = done
            .iter()
            .filter(|c| c.tokens.first() == Some(&expected[c.id as usize]))
            .count();
        println!("== {name} ==");
        println!("  {}", engine.metrics.report());
        println!(
            "  wall {wall:.2}s, prompt tokens {} — accuracy {correct}/{N_REQUESTS} \
             (1 session cancelled mid-stream, blocks reclaimed)\n",
            N_REQUESTS * CTX
        );
        assert_eq!(engine.metrics.cancelled, 1);
        assert_eq!(engine.sched.blocks.used(), 0);
    }
    Ok(())
}
