//! Quickstart: build a SynthLM, calibrate a Kascade plan on a small dev
//! set, and answer one long-context retrieval prompt with dense vs Kascade
//! attention — showing identical answers at a fraction of the attention work.
//!
//! Run: `cargo run --release --example quickstart`

use kascade::kascade::{calibrate, CalibrateOptions};
use kascade::model::SynthSpec;
use kascade::sparse::{DensePolicy, KascadePolicy, SparsePolicy};
use kascade::tensor::argmax;
use kascade::workload::WorkloadGen;

fn main() -> anyhow::Result<()> {
    // 1. a synthetic long-context model with wired retrieval circuits
    let spec = SynthSpec::eval_base(42);
    let model = spec.build();

    // 2. offline calibration (the paper's deployment recipe, Sec. 3.3):
    //    similarity matrix -> DP anchor selection -> head remapping
    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let dev_prompts: Vec<Vec<u32>> = (0..3).map(|_| dev.dev_prompt(1024)).collect();
    let cal = calibrate(&model, &dev_prompts, &CalibrateOptions::default());
    println!("anchor layers: {:?} (of {})", cal.plan.anchors, model.cfg.n_layers);

    // 3. one retrieval task: fact planted deep in a 2048-token context
    let mut gen = WorkloadGen::new(&spec, 7);
    let task = gen.longbench(kascade::workload::Category::Sqa, 2048);
    let answer = task.expect[0];

    let run = |name: &str, mut policy: Box<dyn SparsePolicy>| {
        let mut st = model.new_state(task.prompt.len() + 8);
        let (logits, _) = model.prefill(&task.prompt, &mut st, policy.as_mut(), None);
        let got = argmax(&logits) as u32;
        let work = st.cost.score_key_reads + st.cost.attend_kv_reads;
        println!(
            "{name:>8}: answer token {got} ({}) — attention key/value reads {work}",
            if got == answer { "correct" } else { "WRONG" }
        );
        (got, work)
    };

    let (d_tok, d_work) = run("dense", Box::new(DensePolicy));
    let (k_tok, k_work) = run("kascade", Box::new(KascadePolicy::new(cal.plan.clone())));
    assert_eq!(d_tok, answer);
    assert_eq!(k_tok, answer);
    println!(
        "\nsame answer, {:.1}x less attention work (prefill, k = 10% / min 128)",
        d_work as f64 / k_work as f64
    );
    Ok(())
}
