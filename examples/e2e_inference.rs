//! END-TO-END driver over the full three-layer stack (DESIGN.md §3):
//! Pallas kernels -> AOT HLO artifacts -> PJRT execution driven by the
//! Rust coordinator, serving batched retrieval requests with dense vs
//! Kascade attention and reporting accuracy, latency and throughput.
//!
//! Requires `make artifacts` first.
//! Run: `cargo run --release --example e2e_inference`

use kascade::config::ServeConfig;
use kascade::coordinator::{PjrtBackend, Request};
use kascade::kascade::{calibrate, CalibrateOptions, KascadePlan};
use kascade::model::SynthSpec;
use kascade::runtime::{PjrtModel, Runtime};
use kascade::server::{Engine, LocalBackendFactory};
use kascade::workload::{Category, WorkloadGen};
use std::path::Path;
use std::sync::Arc;

const CTX: usize = 400; // fits the 512-token prefill bucket
const N_REQUESTS: usize = 6;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        anyhow::bail!("artifacts/manifest.json missing — run `make artifacts` first");
    }

    // L2/L1 products: load HLO artifacts, upload SynthLM weights once.
    let spec = SynthSpec::pjrt_small(42);
    let native = spec.build(); // weight source + calibration oracle
    let rt = Runtime::load(artifacts)?;
    println!(
        "loaded {} artifacts (decode buckets {:?}, prefill buckets {:?})",
        rt.manifest.artifacts.len(),
        rt.manifest.decode_l,
        rt.manifest.prefill_t
    );
    let pjrt = Arc::new(PjrtModel::new(rt, &native.w)?);

    // offline calibration on the native oracle (python never runs at serve
    // time; calibration is a build-time step like the paper's)
    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let prompts: Vec<Vec<u32>> = (0..3).map(|_| dev.dev_prompt(CTX)).collect();
    let plan = calibrate(&native, &prompts, &CalibrateOptions::default()).plan;
    println!("calibrated anchors: {:?}", plan.anchors);

    let cfg = ServeConfig {
        block_size: 16,
        num_blocks: 4096,
        max_running: 4,
        token_budget: 2048,
        prefill_chunk: 512,
        queue_cap: 64,
        workers: 1,
        ..ServeConfig::default()
    };

    for (name, plan) in [("dense", None::<KascadePlan>), ("kascade", Some(plan))] {
        let pjrt = pjrt.clone();
        let plan = plan.map(Arc::new);
        let factory: LocalBackendFactory = Box::new(move |_req| {
            Box::new(PjrtBackend::new(pjrt.clone(), plan.clone()))
        });
        let mut engine = Engine::new(cfg.clone(), factory);
        let mut gen = WorkloadGen::new(&spec, 0x7E57);
        let mut expected = Vec::new();
        let mut handles = Vec::new();
        for _ in 0..N_REQUESTS {
            let t = gen.longbench(Category::Sqa, CTX);
            expected.push(t.expect[0]);
            handles.push(
                engine
                    .submit(Request::new(t.prompt).max_new(2).stop(t.expect[0]))
                    .expect("admission"),
            );
        }
        let t0 = std::time::Instant::now();
        let done = engine.run_to_completion(&mut handles);
        let wall = t0.elapsed().as_secs_f64();
        let correct = done
            .iter()
            .filter(|c| c.tokens.first() == Some(&expected[c.id as usize]))
            .count();
        println!("\n== {name} (PJRT path) ==");
        println!("  {}", engine.metrics.report());
        println!("  wall {wall:.2}s — retrieval accuracy {correct}/{N_REQUESTS}");
        assert_eq!(correct, N_REQUESTS, "{name}: retrieval must be exact on the PJRT path");
    }
    println!("\ne2e OK: all three layers compose (Pallas -> HLO -> PJRT -> coordinator)");
    Ok(())
}
