//! Anchor-layer calibration walkthrough: builds the Eq.-3 similarity
//! matrix on a development set, shows the importance weights, runs the
//! Algorithm-1 DP at several anchor budgets, derives head maps, and writes
//! the deployable plan JSON.
//!
//! Run: `cargo run --release --example calibrate_anchors`

use kascade::kascade::{calibrate, select_anchors, CalibrateOptions};
use kascade::model::SynthSpec;
use kascade::workload::WorkloadGen;

fn main() -> anyhow::Result<()> {
    let spec = SynthSpec::eval_base(42);
    let model = spec.build();
    println!(
        "SynthLM: {} layers, planted match blocks at {:?}",
        model.cfg.n_layers, spec.block_starts
    );

    let mut dev = WorkloadGen::new(&spec, 0xDE5);
    let prompts: Vec<Vec<u32>> = (0..4).map(|_| dev.dev_prompt(1024)).collect();
    let cal = calibrate(&model, &prompts, &CalibrateOptions::default());

    println!("\ncross-layer similarity (unweighted, sim_k={}):", cal.sim.k);
    let m = cal.sim.layer_matrix(false);
    for a in 0..model.cfg.n_layers {
        let row: Vec<String> = (0..model.cfg.n_layers)
            .map(|b| if b >= a { format!("{:.2}", m.get(a, b)) } else { "    ".into() })
            .collect();
        println!("  L{a:>2}: {}", row.join(" "));
    }

    println!("\nimportance weights w_l = 1 - cos(x, y):");
    for (l, w) in cal.importance.iter().enumerate() {
        let bar = "#".repeat((w / cal.importance[1].max(1e-9) * 40.0) as usize);
        println!("  L{l:>2} {w:.5} {bar}");
    }

    println!("\nAlgorithm 1 across anchor budgets (importance-weighted):");
    let weighted = cal.sim.layer_matrix(true);
    for budget in 2..=8 {
        let (anchors, obj) = select_anchors(&weighted, budget);
        println!("  M={budget}: anchors {anchors:?}  objective {obj:.4}");
    }

    println!("\nselected plan (M=5): anchors {:?}", cal.plan.anchors);
    for (l, hm) in cal.plan.head_map.iter().enumerate() {
        println!("  layer {l:>2} {:?} head_map {:?}", cal.plan.role(l), hm);
    }

    std::fs::create_dir_all("results")?;
    let path = std::path::Path::new("results/kascade_plan.json");
    cal.plan.save(path)?;
    println!("\nplan written to {}", path.display());
    Ok(())
}
