"""AOT-lower every model op x shape bucket to HLO text artifacts.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every entry point is lowered with return_tuple=True, so the Rust runtime
always receives a tuple literal and unpacks by element.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
from dataclasses import asdict
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

CFG = M.ModelConfig()
DECODE_L = [512, 1024, 2048]  # KV-cache capacity buckets (decode)
PREFILL_T = [128, 512]  # full-prompt prefill buckets (T == L)
TILE = 128  # prefill Q-tile / pooling tile (paper default)


def k_rule(L: int) -> int:
    """Paper Sec. 4.1: k = min(max(0.1 * L, 128), L)."""
    return int(min(max(0.1 * L, 128), L))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _tuple_fn(fn):
    """Wrap so the output is always a flat tuple of arrays."""

    def wrapped(*a):
        out = fn(*a)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def entry_points(cfg: M.ModelConfig):
    """Yield (name, fn, arg_specs, params) for every artifact."""
    D, d, F, V = cfg.d_model, cfg.d_head, cfg.d_ff, cfg.vocab
    nq, nkv = cfg.n_q_heads, cfg.n_kv_heads
    i32 = jnp.int32
    layer_w = [
        ("wo", (nq * d, D)),
        ("ln2", (D,)),
        ("w1", (D, F)),
        ("w3", (D, F)),
        ("w2", (F, D)),
    ]

    # --- embedding / projection / mlp ops -------------------------------
    for T, tag in [(1, "decode")] + [(t, f"prefill_t{t}") for t in PREFILL_T]:
        yield (
            f"embed_{tag}",
            M.embed,
            [_spec((T,), i32), _spec((V, D))],
            {"kind": "embed", "t": T},
        )
        yield (
            f"qkv_{tag}",
            partial(M.qkv, cfg=cfg),
            [
                _spec((T, D)),
                _spec((D,)),
                _spec((D, nq * d)),
                _spec((D, nkv * d)),
                _spec((D, nkv * d)),
                _spec((T,), i32),
            ],
            {"kind": "qkv", "t": T},
        )
        yield (
            f"post_{tag}",
            M.post,
            [_spec((T, D)), _spec((nq, T, d))] + [_spec(s) for _, s in layer_w],
            {"kind": "post", "t": T},
        )
    yield (
        "logits_decode",
        M.logits,
        [_spec((1, D)), _spec((D,)), _spec((D, V))],
        {"kind": "logits", "t": 1},
    )

    # --- decode attention variants --------------------------------------
    for L in DECODE_L:
        kk = k_rule(L)
        qs, ks, vs = _spec((nq, d)), _spec((nkv, L, d)), _spec((nkv, L, d))
        ln = _spec((1,), i32)
        yield (
            f"attn_dense_decode_l{L}",
            M.attn_dense_decode,
            [qs, ks, vs, ln],
            {"kind": "attn_dense_decode", "l": L},
        )
        yield (
            f"attn_anchor_decode_l{L}",
            partial(M.attn_anchor_decode, kk=kk),
            [qs, ks, vs, ln],
            {"kind": "attn_anchor_decode", "l": L, "k": kk},
        )
        yield (
            f"attn_anchor0_decode_l{L}",
            partial(M.attn_anchor0_decode, kk=kk),
            [qs, ks, vs, ln],
            {"kind": "attn_anchor0_decode", "l": L, "k": kk},
        )
        yield (
            f"attn_reuse_decode_l{L}",
            M.attn_reuse_decode,
            [qs, ks, vs, _spec((nkv, kk), i32)],
            {"kind": "attn_reuse_decode", "l": L, "k": kk},
        )

    # --- prefill attention variants (full prompt: L == T) ----------------
    for T in PREFILL_T:
        kk = k_rule(T)
        nt = T // TILE
        qs, ks, vs = _spec((nq, T, d)), _spec((nkv, T, d)), _spec((nkv, T, d))
        ln = _spec((1,), i32)
        yield (
            f"attn_dense_prefill_t{T}",
            M.attn_dense_prefill,
            [qs, ks, vs, ln],
            {"kind": "attn_dense_prefill", "t": T, "l": T},
        )
        yield (
            f"attn_anchor_prefill_t{T}",
            partial(M.attn_anchor_prefill, kk=kk, tile=TILE),
            [qs, ks, vs, ln],
            {"kind": "attn_anchor_prefill", "t": T, "l": T, "k": kk, "tile": TILE},
        )
        yield (
            f"attn_anchor0_prefill_t{T}",
            partial(M.attn_anchor0_prefill, kk=kk, tile=TILE),
            [qs, ks, vs, ln],
            {"kind": "attn_anchor0_prefill", "t": T, "l": T, "k": kk, "tile": TILE},
        )
        yield (
            f"attn_reuse_prefill_t{T}",
            partial(M.attn_reuse_prefill, tile=TILE),
            [qs, ks, vs, _spec((nkv, nt, kk), i32)],
            {"kind": "attn_reuse_prefill", "t": T, "l": T, "k": kk, "tile": TILE},
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "config": asdict(CFG),
        "buckets": {"decode_l": DECODE_L, "prefill_t": PREFILL_T, "tile": TILE},
        "k_rule": {"frac": 0.1, "min": 128},
        "artifacts": {},
    }
    for name, fn, specs, params in entry_points(CFG):
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(_tuple_fn(fn)).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(o.shape), "dtype": str(o.dtype)}
            for o in lowered.out_info
        ]
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype.name if hasattr(s.dtype, 'name') else s.dtype)}
                for s in specs
            ],
            "outputs": out_shapes,
            **params,
        }
        print(f"  lowered {name} ({len(text) / 1024:.0f} KiB)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
