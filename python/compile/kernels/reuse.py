"""Pallas sparse Top-k (reuse-layer) attention kernels.

Reuse layers consume the Top-k indices produced by the previous anchor
layer (after head remapping, which the Rust coordinator applies as a
row-gather on the index tensor before invoking these kernels).  The key
loads are non-contiguous gathers — the paper (Sec. 3.6) notes each key row
is large enough (~256 B) that this costs little; on TPU this maps to a
dynamic-slice stream from HBM into VMEM.

idx entries < 0 are padding and masked out of the softmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _reuse_decode_kernel(q_ref, k_ref, v_ref, idx_ref, o_ref, *, scale):
    """One KV head: gather k rows by idx, attend.  Blocks: q [1,g,d],
    k/v [1,L,d], idx [1,kk], o [1,g,d]."""
    q = q_ref[0]  # [g, d]
    idx = idx_ref[0]  # [kk]
    safe = jnp.maximum(idx, 0)
    kg = k_ref[0, safe, :]  # gather: [kk, d]
    vg = v_ref[0, safe, :]
    s = jnp.dot(q, kg.T) * scale  # [g, kk]
    s = jnp.where((idx >= 0)[None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o_ref[0] = (jnp.dot(p, vg) / p.sum(axis=-1, keepdims=True)).astype(o_ref.dtype)


def reuse_decode(q, k, v, idx):
    """Sparse decode attention over per-KV-head Top-k indices (Pallas).

    q: [n_q, d], k/v: [n_kv, L, d], idx: [n_kv, kk] int32 (-1 = padding).
    Returns [n_q, d].
    """
    n_q, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    kk = idx.shape[1]
    qg = q.reshape(n_kv, g, d).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_reuse_decode_kernel, scale=1.0 / d**0.5),
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, kk), lambda h: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, g, d), q.dtype),
        interpret=True,
    )(qg, k.astype(jnp.float32), v.astype(jnp.float32), idx.astype(jnp.int32))
    return out.reshape(n_q, d)


def _reuse_prefill_kernel(q_ref, k_ref, v_ref, idx_ref, o_ref, *, scale, tile, offs):
    """One (kv head, Q-tile): gather + causally-masked sparse attention.
    Blocks: q [1,1,g*tile,d] (tile-major: [g, tile] flattened), k/v [1,L,d],
    idx [1,1,kk]."""
    t = pl.program_id(1)
    q = q_ref[0, 0]  # [g*tile, d]
    gt, d = q.shape
    g = gt // tile
    idx = idx_ref[0, 0]  # [kk]
    safe = jnp.maximum(idx, 0)
    kg = k_ref[0, safe, :]  # [kk, d]
    vg = v_ref[0, safe, :]
    s = jnp.dot(q, kg.T) * scale  # [g*tile, kk]
    qpos = offs + t * tile + jax.lax.iota(jnp.int32, tile)  # [tile]
    qpos = jnp.tile(qpos, (g,))  # row r of q is (head r//tile? no: g-major)
    valid = (idx >= 0)[None, :] & (safe[None, :] <= qpos[:, None])
    s = jnp.where(valid, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    denom = p.sum(axis=-1, keepdims=True)
    o_ref[0, 0] = (jnp.dot(p, vg) / jnp.maximum(denom, 1e-30)).astype(o_ref.dtype)


def reuse_prefill(q, k, v, idx, tile: int):
    """Sparse causal prefill attention with tile-shared Top-k indices.

    q: [n_q, T, d], k/v: [n_kv, L, d], idx: [n_kv, T//tile, kk] int32.
    All g query heads of a KV group and all `tile` consecutive queries in a
    tile share one index set (paper Sec. 3.4).  Returns [n_q, T, d].
    """
    n_q, T, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    nt = T // tile
    kk = idx.shape[-1]
    # Rearrange to [n_kv, nt, g*tile, d], g-major rows to match the kernel.
    qr = (
        q.reshape(n_kv, g, nt, tile, d)
        .transpose(0, 2, 1, 3, 4)
        .reshape(n_kv, nt, g * tile, d)
        .astype(jnp.float32)
    )
    out = pl.pallas_call(
        functools.partial(
            _reuse_prefill_kernel, scale=1.0 / d**0.5, tile=tile, offs=L - T
        ),
        grid=(n_kv, nt),
        in_specs=[
            pl.BlockSpec((1, 1, g * tile, d), lambda h, t: (h, t, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((1, 1, kk), lambda h, t: (h, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * tile, d), lambda h, t: (h, t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, nt, g * tile, d), q.dtype),
        interpret=True,
    )(qr, k.astype(jnp.float32), v.astype(jnp.float32), idx.astype(jnp.int32))
    return (
        out.reshape(n_kv, nt, g, tile, d)
        .transpose(0, 2, 1, 3, 4)
        .reshape(n_q, T, d)
    )
