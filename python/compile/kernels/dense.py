"""Pallas flash-style dense GQA attention kernels (decode + causal prefill).

TPU adaptation of the paper's TileLang/FA3 baselines (DESIGN.md
§Hardware-Adaptation): the HBM<->SMEM threadblock schedule becomes an
HBM<->VMEM BlockSpec schedule; Q-tiles of `TILE_Q` queries (128 by default,
matching the paper) stream K/V tiles of `TILE_K` keys through an online
softmax.  `interpret=True` everywhere — the CPU PJRT plugin cannot run
Mosaic custom-calls; the lowered HLO is what the Rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
TILE_Q = 128  # prefill Q-tile (matches the paper's kernel + pooling tile)
TILE_K = 256  # K/V tile streamed through VMEM


def _pick_tile_k(L: int) -> int:
    """Largest K-tile <= TILE_K that divides L (context lengths are padded
    to a multiple of 128 by the coordinator; smaller L runs untiled)."""
    for t in (TILE_K, 128, 64, 32, 16, 8, 4, 2, 1):
        if t <= L and L % t == 0:
            return t
    return L


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, tile_k, scale):
    """One KV head: q [1,g,d] x K/V [1,L,d] -> o [1,g,d] (online softmax)."""
    q = q_ref[0]  # [g, d]
    g, d = q.shape
    length = len_ref[0]
    L = k_ref.shape[1]
    nblk = L // tile_k

    def body(i, carry):
        m, l, acc = carry
        kblk = k_ref[0, pl.ds(i * tile_k, tile_k), :]  # [tile_k, d]
        vblk = v_ref[0, pl.ds(i * tile_k, tile_k), :]
        s = jnp.dot(q, kblk.T) * scale  # [g, tile_k] (MXU-shaped)
        kpos = i * tile_k + jax.lax.iota(jnp.int32, tile_k)
        s = jnp.where((kpos < length)[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, vblk)
        return m_new, l_new, acc_new

    init = (
        jnp.full((g,), NEG_INF, jnp.float32),
        jnp.zeros((g,), jnp.float32),
        jnp.zeros((g, d), jnp.float32),
    )
    _, l, acc = jax.lax.fori_loop(0, nblk, body, init)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def dense_decode(q, k, v, length):
    """Dense GQA decode attention (Pallas).

    q: [n_q, d], k/v: [n_kv, L, d] (L a multiple of TILE_K), length: [1]
    int32 valid-key count.  Returns [n_q, d].
    """
    n_q, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    qg = q.reshape(n_kv, g, d).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, tile_k=_pick_tile_k(L), scale=1.0 / d**0.5),
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((1, g, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, g, d), q.dtype),
        interpret=True,
    )(length.astype(jnp.int32), qg, k.astype(jnp.float32), v.astype(jnp.float32))
    return out.reshape(n_q, d)


def _prefill_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, tile_q, tile_k, scale, offs):
    """One (q head, Q-tile): causal flash attention over streamed K tiles."""
    q = q_ref[0]  # [tile_q, d]
    tq, d = q.shape
    t = pl.program_id(1)
    length = len_ref[0]
    qpos = offs + t * tile_q + jax.lax.iota(jnp.int32, tile_q)  # absolute
    # K tiles needed: up to the causal limit of the last query in the tile.
    hi = (offs + (t + 1) * tile_q + tile_k - 1) // tile_k
    nblk_total = k_ref.shape[1] // tile_k
    hi = jnp.minimum(hi, nblk_total)

    def body(i, carry):
        m, l, acc = carry
        kblk = k_ref[0, pl.ds(i * tile_k, tile_k), :]
        vblk = v_ref[0, pl.ds(i * tile_k, tile_k), :]
        s = jnp.dot(q, kblk.T) * scale  # [tile_q, tile_k]
        kpos = i * tile_k + jax.lax.iota(jnp.int32, tile_k)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos < length)[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, vblk)
        return m_new, l_new, acc_new

    init = (
        jnp.full((tq,), NEG_INF, jnp.float32),
        jnp.zeros((tq,), jnp.float32),
        jnp.zeros((tq, d), jnp.float32),
    )
    _, l, acc = jax.lax.fori_loop(0, hi, body, init)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def dense_prefill(q, k, v, length, tile_q: int = TILE_Q):
    """Dense causal GQA prefill attention (Pallas flash).

    q: [n_q, T, d] (T a multiple of tile_q), k/v: [n_kv, L, d] with L >= T;
    query t attends to keys [0, L - T + t].  length: [1] int32.
    Returns [n_q, T, d].
    """
    n_q, T, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    nt = T // tile_q
    tile_k = _pick_tile_k(L)
    out = pl.pallas_call(
        functools.partial(
            _prefill_kernel,
            tile_q=tile_q,
            tile_k=tile_k,
            scale=1.0 / d**0.5,
            offs=L - T,
        ),
        grid=(n_q, nt),
        in_specs=[
            pl.BlockSpec((1,), lambda h, t: (0,)),
            pl.BlockSpec((1, tile_q, d), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, L, d), lambda h, t: (h // g, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h, t: (h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, d), lambda h, t: (h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_q, T, d), q.dtype),
        interpret=True,
    )(
        length.astype(jnp.int32),
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
    )
    return out
