"""Pallas multi-pass anchor-layer kernels (paper Sec. 3.6).

Anchor layers must produce (a) the layer's attention output and (b) fresh
Top-k indices for the downstream reuse layers.  Post-softmax pooling needs
the full row sum, so this cannot be done in one pass:

  pass 1  decode : raw QK^T scores written out (half the work of attention)
          prefill: flash-style row max + row sum-exp only (no PV matmul)
  pass 2  decode : softmax over stored scores, pooled across the GQA group
          prefill: recompute QK^T per tile, normalize with pass-1 stats,
                   pool across (GQA group x Q-tile)
  pass 3  Top-k over the pooled weights (jax.lax.top_k — a small dense op
          that XLA fuses into the same HLO module)
  pass 4  sparse Top-k attention over the fresh indices (reuse kernels)

For anchor layer 0 the paper computes full dense attention in pass 1 and
skips pass 4 — `anchor0_*` below.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dense as dense_k
from . import reuse as reuse_k
from .dense import _pick_tile_k

NEG_INF = -1e30
TILE_K = 256


# ---------------------------------------------------------------------------
# decode passes
# ---------------------------------------------------------------------------


def _decode_scores_kernel(len_ref, q_ref, k_ref, s_ref, *, scale):
    """Pass 1 (decode): raw masked scores [1,g,L] for one KV head."""
    q = q_ref[0]  # [g, d]
    kk = k_ref[0]  # [L, d]
    length = len_ref[0]
    s = jnp.dot(q, kk.T) * scale
    kpos = jax.lax.iota(jnp.int32, kk.shape[0])
    s_ref[0] = jnp.where((kpos < length)[None, :], s, NEG_INF).astype(s_ref.dtype)


def decode_scores_pass(q, k, length):
    """Pass 1: raw scores [n_kv, g, L] (written to HBM, as in the paper)."""
    n_q, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    qg = q.reshape(n_kv, g, d).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_decode_scores_kernel, scale=1.0 / d**0.5),
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((1, g, d), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, L), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, g, L), jnp.float32),
        interpret=True,
    )(length.astype(jnp.int32), qg, k.astype(jnp.float32))


def _decode_pool_kernel(s_ref, p_ref):
    """Pass 2 (decode): stable softmax per row, mean-pool the GQA group."""
    s = s_ref[0]  # [g, L]
    m = s.max(axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / e.sum(axis=-1, keepdims=True)
    p_ref[0] = p.mean(axis=0).astype(p_ref.dtype)


def decode_pool_pass(scores):
    """Pass 2: pooled post-softmax weights [n_kv, L]."""
    n_kv, g, L = scores.shape
    return pl.pallas_call(
        _decode_pool_kernel,
        grid=(n_kv,),
        in_specs=[pl.BlockSpec((1, g, L), lambda h: (h, 0, 0))],
        out_specs=pl.BlockSpec((1, L), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, L), jnp.float32),
        interpret=True,
    )(scores)


def topk_pass(pooled, kk: int):
    """Pass 3: Top-k indices from pooled weights; weight-0 slots -> -1.

    Implemented with argsort rather than `jax.lax.top_k`: top_k lowers to a
    `topk(..., largest=true)` HLO op that predates xla_extension 0.5.1's
    text parser (the version behind the Rust `xla` crate), while argsort
    lowers to the ancient, universally-supported `sort` op.
    """
    idx = jnp.argsort(-pooled, axis=-1)[..., :kk].astype(jnp.int32)
    w = jnp.take_along_axis(pooled, idx, axis=-1)
    return jnp.where(w > 0.0, idx, -1)


def anchor_decode(q, k, v, length, kk: int):
    """Full anchor decode pipeline: (out [n_q,d], idx [n_kv,kk])."""
    scores = decode_scores_pass(q, k, length)
    pooled = decode_pool_pass(scores)
    idx = topk_pass(pooled, kk)
    out = reuse_k.reuse_decode(q, k, v, idx)
    return out, idx


def anchor0_decode(q, k, v, length, kk: int):
    """Anchor layer 0: dense output (no pass 4) + Top-k indices."""
    out = dense_k.dense_decode(q, k, v, length)
    pooled = decode_pool_pass(decode_scores_pass(q, k, length))
    idx = topk_pass(pooled, kk)
    return out, idx


# ---------------------------------------------------------------------------
# prefill passes
# ---------------------------------------------------------------------------


def _prefill_stats_kernel(len_ref, q_ref, k_ref, m_ref, l_ref, *, tile_q, tile_k, scale, offs):
    """Pass 1 (prefill): row max + row sum-exp, no PV matmul."""
    q = q_ref[0]  # [tile_q, d]
    t = pl.program_id(1)
    length = len_ref[0]
    qpos = offs + t * tile_q + jax.lax.iota(jnp.int32, tile_q)
    nblk_total = k_ref.shape[1] // tile_k
    hi = jnp.minimum((offs + (t + 1) * tile_q + tile_k - 1) // tile_k, nblk_total)

    def body(i, carry):
        m, l = carry
        kblk = k_ref[0, pl.ds(i * tile_k, tile_k), :]
        s = jnp.dot(q, kblk.T) * scale
        kpos = i * tile_k + jax.lax.iota(jnp.int32, tile_k)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos < length)[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        l_new = l * jnp.exp(m - m_new) + jnp.exp(s - m_new[:, None]).sum(axis=-1)
        return m_new, l_new

    m0 = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q.shape[0],), jnp.float32)
    m, l = jax.lax.fori_loop(0, hi, body, (m0, l0))
    m_ref[0] = m.astype(m_ref.dtype)
    l_ref[0] = l.astype(l_ref.dtype)


def prefill_stats_pass(q, k, length, tile_q: int = dense_k.TILE_Q):
    """Pass 1: (rowmax [n_q, T], rowsumexp [n_q, T])."""
    n_q, T, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    nt = T // tile_q
    tile_k = _pick_tile_k(L)
    return pl.pallas_call(
        functools.partial(
            _prefill_stats_kernel,
            tile_q=tile_q,
            tile_k=tile_k,
            scale=1.0 / d**0.5,
            offs=L - T,
        ),
        grid=(n_q, nt),
        in_specs=[
            pl.BlockSpec((1,), lambda h, t: (0,)),
            pl.BlockSpec((1, tile_q, d), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, L, d), lambda h, t: (h // g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile_q), lambda h, t: (h, t)),
            pl.BlockSpec((1, tile_q), lambda h, t: (h, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_q, T), jnp.float32),
            jax.ShapeDtypeStruct((n_q, T), jnp.float32),
        ],
        interpret=True,
    )(
        length.astype(jnp.int32),
        q.astype(jnp.float32),
        k.astype(jnp.float32),
    )


def _prefill_pool_kernel(
    len_ref, q_ref, k_ref, m_ref, l_ref, p_ref, *, tile, tile_k, scale, offs, g
):
    """Pass 2 (prefill): recompute scores, normalize with pass-1 stats,
    pool over (GQA group x Q-tile).  q block is [1,1,g*tile,d] (g-major)."""
    t = pl.program_id(1)
    q = q_ref[0, 0]  # [g*tile, d]
    m = m_ref[0, 0]  # [g*tile]
    l = l_ref[0, 0]
    length = len_ref[0]
    qpos1 = offs + t * tile + jax.lax.iota(jnp.int32, tile)
    qpos = jnp.tile(qpos1, (g,))  # row r -> query position (g-major rows)
    nblk_total = k_ref.shape[1] // tile_k
    hi = jnp.minimum((offs + (t + 1) * tile + tile_k - 1) // tile_k, nblk_total)
    nblk = nblk_total  # static loop over all k tiles; zero past `hi`

    def body(i, _):
        kblk = k_ref[0, pl.ds(i * tile_k, tile_k), :]
        s = jnp.dot(q, kblk.T) * scale  # [g*tile, tile_k]
        kpos = i * tile_k + jax.lax.iota(jnp.int32, tile_k)
        mask = (kpos[None, :] <= qpos[:, None]) & (kpos < length)[None, :]
        p = jnp.where(mask, jnp.exp(s - m[:, None]) / l[:, None], 0.0)
        live = (i < hi).astype(jnp.float32)
        pl.store(
            p_ref,
            (0, 0, pl.ds(i * tile_k, tile_k)),
            (p.mean(axis=0) * live).astype(p_ref.dtype),
        )
        return 0

    jax.lax.fori_loop(0, nblk, body, 0)


def prefill_pool_pass(q, k, m, l, length, tile: int = dense_k.TILE_Q):
    """Pass 2: pooled post-softmax weights [n_kv, T//tile, L]."""
    n_q, T, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    nt = T // tile
    tile_k = _pick_tile_k(L)
    qr = (
        q.reshape(n_kv, g, nt, tile, d)
        .transpose(0, 2, 1, 3, 4)
        .reshape(n_kv, nt, g * tile, d)
        .astype(jnp.float32)
    )
    mr = m.reshape(n_kv, g, nt, tile).transpose(0, 2, 1, 3).reshape(n_kv, nt, g * tile)
    lr = l.reshape(n_kv, g, nt, tile).transpose(0, 2, 1, 3).reshape(n_kv, nt, g * tile)
    return pl.pallas_call(
        functools.partial(
            _prefill_pool_kernel,
            tile=tile,
            tile_k=tile_k,
            scale=1.0 / d**0.5,
            offs=L - T,
            g=g,
        ),
        grid=(n_kv, nt),
        in_specs=[
            pl.BlockSpec((1,), lambda h, t: (0,)),
            pl.BlockSpec((1, 1, g * tile, d), lambda h, t: (h, t, 0, 0)),
            pl.BlockSpec((1, L, d), lambda h, t: (h, 0, 0)),
            pl.BlockSpec((1, 1, g * tile), lambda h, t: (h, t, 0)),
            pl.BlockSpec((1, 1, g * tile), lambda h, t: (h, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L), lambda h, t: (h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_kv, nt, L), jnp.float32),
        interpret=True,
    )(length.astype(jnp.int32), qr, k.astype(jnp.float32), mr, lr)


def anchor_prefill(q, k, v, length, kk: int, tile: int = dense_k.TILE_Q):
    """Full anchor prefill pipeline: (out [n_q,T,d], idx [n_kv,T//tile,kk])."""
    m, l = prefill_stats_pass(q, k, length, tile)
    pooled = prefill_pool_pass(q, k, m, l, length, tile)
    idx = topk_pass(pooled, kk)
    out = reuse_k.reuse_prefill(q, k, v, idx, tile)
    return out, idx


def anchor0_prefill(q, k, v, length, kk: int, tile: int = dense_k.TILE_Q):
    """Anchor layer 0 prefill: dense output + Top-k indices (no pass 4)."""
    out = dense_k.dense_prefill(q, k, v, length, tile)
    m, l = prefill_stats_pass(q, k, length, tile)
    pooled = prefill_pool_pass(q, k, m, l, length, tile)
    idx = topk_pass(pooled, kk)
    return out, idx
