"""Pure-jnp reference oracles for every Kascade kernel.

These are the ground truth the Pallas kernels (dense.py, anchor.py,
reuse.py) are tested against at build time.  Everything here mirrors the
math in the paper:

  * dense scaled-dot-product GQA attention (Eq. 1-2), decode + causal prefill
  * oracle Top-k attention (Sec. 3.1)
  * post-/pre-softmax tile pooling (Sec. 3.4)
  * sparse attention over an explicit index set (reuse layers, Sec. 3.2)
  * the anchor multi-pass pipeline outputs (Sec. 3.6)

Shape conventions (single sequence; batching is the coordinator's job):
  q  decode : [n_q, d]          prefill : [n_q, T, d]
  K,V       : [n_kv, L, d]
  idx decode: [n_kv, k]         prefill : [n_kv, n_tiles, k]
Group size g = n_q // n_kv; query head h maps to kv head h // g.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _scale(d: int) -> float:
    return 1.0 / (d**0.5)


# ---------------------------------------------------------------------------
# dense attention
# ---------------------------------------------------------------------------


def dense_decode(q, k, v, length=None):
    """Dense GQA decode attention.

    q: [n_q, d], k/v: [n_kv, L, d]. `length` masks keys >= length (padding).
    Returns [n_q, d].
    """
    n_q, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    qg = q.reshape(n_kv, g, d)
    s = jnp.einsum("hgd,hld->hgl", qg, k) * _scale(d)
    if length is not None:
        mask = jnp.arange(L)[None, None, :] < length
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hgl,hld->hgd", p, v)
    return out.reshape(n_q, d)


def dense_prefill(q, k, v, length=None):
    """Dense causal GQA prefill attention.

    q: [n_q, T, d], k/v: [n_kv, L, d] with L >= T; query t attends to keys
    [0, L - T + t].  Returns [n_q, T, d].
    """
    n_q, T, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    qg = q.reshape(n_kv, g, T, d)
    s = jnp.einsum("hgtd,hld->hgtl", qg, k) * _scale(d)
    offs = L - T
    causal = jnp.arange(L)[None, :] <= (jnp.arange(T)[:, None] + offs)
    if length is not None:
        causal = causal & (jnp.arange(L)[None, :] < length)
    s = jnp.where(causal[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hgtl,hld->hgtd", p, v)
    return out.reshape(n_q, T, d)


# ---------------------------------------------------------------------------
# scores + pooling (anchor pass 1 + 2)
# ---------------------------------------------------------------------------


def decode_scores(q, k, length=None):
    """Per-query-head post-softmax distributions: [n_q, L]."""
    n_q, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    qg = q.reshape(n_kv, g, d)
    s = jnp.einsum("hgd,hld->hgl", qg, k) * _scale(d)
    if length is not None:
        s = jnp.where(jnp.arange(L)[None, None, :] < length, s, NEG_INF)
    return jax.nn.softmax(s, axis=-1).reshape(n_q, L)


def pool_post_softmax_decode(q, k, length=None):
    """GQA pooling: mean of post-softmax distributions over the group.

    Returns [n_kv, L] pooled attention weights (paper Sec. 3.4, decode).
    """
    n_q, _ = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    p = decode_scores(q, k, length).reshape(n_kv, g, L)
    return p.mean(axis=1)


def pool_pre_softmax_decode(q, k, length=None):
    """Pre-softmax pooling: average queries in the group, then one softmax."""
    n_q, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    qbar = q.reshape(n_kv, g, d).mean(axis=1)
    s = jnp.einsum("hd,hld->hl", qbar, k) * _scale(d)
    if length is not None:
        s = jnp.where(jnp.arange(L)[None, :] < length, s, NEG_INF)
    return jax.nn.softmax(s, axis=-1)


def prefill_scores(q, k, length=None):
    """Per-query-head causal post-softmax distributions: [n_q, T, L]."""
    n_q, T, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    qg = q.reshape(n_kv, g, T, d)
    s = jnp.einsum("hgtd,hld->hgtl", qg, k) * _scale(d)
    offs = L - T
    causal = jnp.arange(L)[None, :] <= (jnp.arange(T)[:, None] + offs)
    if length is not None:
        causal = causal & (jnp.arange(L)[None, :] < length)
    s = jnp.where(causal[None, None], s, NEG_INF)
    return jax.nn.softmax(s, axis=-1).reshape(n_q, T, L)


def pool_post_softmax_prefill(q, k, tile: int, length=None):
    """Tile-level post-softmax pooling for prefill (paper Sec. 3.4).

    Pools the per-query post-softmax distributions over (GQA group x tile of
    `tile` consecutive queries).  Returns [n_kv, T // tile, L].
    """
    n_q, T, _ = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    p = prefill_scores(q, k, length).reshape(n_kv, g, T // tile, tile, L)
    return p.mean(axis=(1, 3))


# ---------------------------------------------------------------------------
# Top-k selection + sparse attention (anchor pass 3 + 4, reuse layers)
# ---------------------------------------------------------------------------


def topk_indices(pooled, k: int):
    """Top-k key indices from pooled weights along the last axis (int32)."""
    _, idx = jax.lax.top_k(pooled, k)
    return idx.astype(jnp.int32)


def sparse_decode(q, k, v, idx):
    """Sparse decode attention over an explicit per-kv-head index set.

    q: [n_q, d], k/v: [n_kv, L, d], idx: [n_kv, kk] int32 (entries < 0 are
    masked out — used for padding).  Returns [n_q, d].
    """
    n_q, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    safe = jnp.maximum(idx, 0)
    kg = jnp.take_along_axis(k, safe[:, :, None], axis=1)  # [n_kv, kk, d]
    vg = jnp.take_along_axis(v, safe[:, :, None], axis=1)
    qg = q.reshape(n_kv, g, d)
    s = jnp.einsum("hgd,hkd->hgk", qg, kg) * _scale(d)
    s = jnp.where(idx[:, None, :] >= 0, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("hgk,hkd->hgd", p, vg)
    return out.reshape(n_q, d)


def sparse_prefill(q, k, v, idx, tile: int):
    """Sparse causal prefill attention with tile-shared indices.

    q: [n_q, T, d], k/v: [n_kv, L, d], idx: [n_kv, T // tile, kk] int32.
    Queries in tile t use idx[:, t]; entries < 0 or beyond the causal limit
    of the individual query are masked.  Returns [n_q, T, d].
    """
    n_q, T, d = q.shape
    n_kv, L, _ = k.shape
    g = n_q // n_kv
    nt = T // tile
    offs = L - T
    safe = jnp.maximum(idx, 0)  # [n_kv, nt, kk]
    kg = jnp.take_along_axis(k[:, None], safe[..., None], axis=2)  # [n_kv,nt,kk,d]
    vg = jnp.take_along_axis(v[:, None], safe[..., None], axis=2)
    qg = q.reshape(n_kv, g, nt, tile, d)
    s = jnp.einsum("hgnud,hnkd->hgnuk", qg, kg) * _scale(d)
    qpos = offs + jnp.arange(T).reshape(nt, tile)  # absolute query positions
    valid = (idx[:, None, :, None, :] >= 0) & (
        safe[:, None, :, None, :] <= qpos[None, None, :, :, None]
    )
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # A fully-masked row would produce NaNs; guard (can happen only for
    # padded tiles, which the caller discards).
    p = jnp.where(jnp.isnan(p), 0.0, p)
    out = jnp.einsum("hgnuk,hnkd->hgnud", p, vg)
    return out.reshape(n_q, T, d)


# ---------------------------------------------------------------------------
# end-to-end anchor pipeline (what the multi-pass kernels must reproduce)
# ---------------------------------------------------------------------------


def anchor_decode(q, k, v, kk: int, length=None):
    """Anchor-layer decode: output + fresh Top-k indices.

    Returns (out [n_q, d], idx [n_kv, kk]).  Output is computed via sparse
    attention over the freshly selected indices (paper pass 4); indices are
    selected from post-softmax GQA-pooled weights (passes 1-3).
    """
    pooled = pool_post_softmax_decode(q, k, length)  # [n_kv, L]
    idx = topk_indices(pooled, kk)
    if length is not None:
        valid = jnp.take_along_axis(pooled, idx, axis=-1) > 0.0
        idx = jnp.where(valid, idx, -1)
    out = sparse_decode(q, k, v, idx)
    return out, idx


def anchor_prefill(q, k, v, kk: int, tile: int, length=None):
    """Anchor-layer prefill: output + per-tile Top-k indices.

    Returns (out [n_q, T, d], idx [n_kv, T // tile, kk]).
    """
    pooled = pool_post_softmax_prefill(q, k, tile, length)  # [n_kv, nt, L]
    idx = topk_indices(pooled, kk)
    valid = jnp.take_along_axis(pooled, idx, axis=-1) > 0.0
    idx = jnp.where(valid, idx, -1)
    out = sparse_prefill(q, k, v, idx, tile)
    return out, idx


def remap_indices(idx, head_map):
    """Head remapping (Sec. 3.5): reuse-head h reads anchor head head_map[h].

    idx: [n_kv, ...] anchor index sets; head_map: [n_kv] int32.  Many-to-one
    allowed.  Returns idx rearranged for the reuse layer's heads.
    """
    return idx[head_map]
