"""L2: GQA transformer ops in JAX, calling the Pallas kernels.

The model is decomposed into *layer-granular ops* so the Rust coordinator
owns the per-layer anchor/reuse schedule (DESIGN.md §6): each op below is
AOT-lowered to its own HLO artifact by aot.py, and the Rust runtime invokes
them in sequence, threading KV-cache buffers and Top-k index tensors
through host memory.  Weights are runtime arguments (never baked into the
HLO), so one artifact serves every layer and every model instance.

Architecture (Llama-style, scaled): RMSNorm -> GQA attention (RoPE) ->
residual -> RMSNorm -> SwiGLU MLP -> residual; final RMSNorm + unembed.

Ops (decode, T=1):            Ops (prefill, T tokens):
  embed_decode                  embed_prefill
  qkv_decode                    qkv_prefill
  attn_dense_decode             attn_dense_prefill
  attn_anchor_decode            attn_anchor_prefill
  attn_anchor0_decode           attn_anchor0_prefill
  attn_reuse_decode             attn_reuse_prefill
  post_decode                   post_prefill
  logits
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import anchor as anchor_k
from .kernels import dense as dense_k
from .kernels import ref as ref_k
from .kernels import reuse as reuse_k


@dataclass(frozen=True)
class ModelConfig:
    """SynthLM architecture hyperparameters (mirrors rust/src/model/config.rs)."""

    n_layers: int = 16
    d_model: int = 256
    n_q_heads: int = 8
    n_kv_heads: int = 4
    d_head: int = 32
    d_ff: int = 1024
    vocab: int = 4096
    rope_theta: float = 10000.0

    @property
    def group(self) -> int:
        return self.n_q_heads // self.n_kv_heads


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-5):
    """RMSNorm over the last axis."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, pos, theta: float):
    """Rotary position embedding.  x: [..., T, d] (d even), pos: [T] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# ops (each is an AOT entry point; prefill T and cache L are static shapes)
# ---------------------------------------------------------------------------


def embed(tokens, w_e):
    """tokens [T] int32, w_e [V, D] -> x [T, D]."""
    return w_e[tokens]


def qkv(x, ln_w, wq, wk, wv, pos, cfg: ModelConfig):
    """Pre-attention projection + RoPE.

    x [T, D], pos [T] int32 absolute positions.
    Returns q [n_q, T, d], k [n_kv, T, d], v [n_kv, T, d].
    """
    T = x.shape[0]
    h = rmsnorm(x, ln_w)
    q = (h @ wq).reshape(T, cfg.n_q_heads, cfg.d_head).transpose(1, 0, 2)
    k = (h @ wk).reshape(T, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    v = (h @ wv).reshape(T, cfg.n_kv_heads, cfg.d_head).transpose(1, 0, 2)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    return q, k, v


def post(x, attn_out, wo, ln2_w, w1, w3, w2):
    """Residual + SwiGLU MLP.  x [T, D], attn_out [n_q, T, d] -> x' [T, D]."""
    n_q, T, d = attn_out.shape
    a = attn_out.transpose(1, 0, 2).reshape(T, n_q * d)
    x = x + a @ wo
    h = rmsnorm(x, ln2_w)
    return x + (jax.nn.silu(h @ w1) * (h @ w3)) @ w2


def logits(x, lnf_w, w_u):
    """x [T, D] -> [T, V]."""
    return rmsnorm(x, lnf_w) @ w_u


# attention variants — thin wrappers so aot.py can enumerate them uniformly.


def attn_dense_decode(q, k, v, length):
    return dense_k.dense_decode(q, k, v, length)


def attn_dense_prefill(q, k, v, length):
    return dense_k.dense_prefill(q, k, v, length)


def attn_anchor_decode(q, k, v, length, kk: int):
    return anchor_k.anchor_decode(q, k, v, length, kk)


def attn_anchor0_decode(q, k, v, length, kk: int):
    return anchor_k.anchor0_decode(q, k, v, length, kk)


def attn_reuse_decode(q, k, v, idx):
    return reuse_k.reuse_decode(q, k, v, idx)


def attn_anchor_prefill(q, k, v, length, kk: int, tile: int):
    return anchor_k.anchor_prefill(q, k, v, length, kk, tile)


def attn_anchor0_prefill(q, k, v, length, kk: int, tile: int):
    return anchor_k.anchor0_prefill(q, k, v, length, kk, tile)


def attn_reuse_prefill(q, k, v, idx, tile: int):
    return reuse_k.reuse_prefill(q, k, v, idx, tile)


# ---------------------------------------------------------------------------
# pure-jnp reference model (tests only — never lowered)
# ---------------------------------------------------------------------------


def init_weights(cfg: ModelConfig, seed: int = 0):
    """Random (but well-conditioned) weights as a dict of arrays."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, cfg.n_layers * 8 + 3)
    D, d, F = cfg.d_model, cfg.d_head, cfg.d_ff

    def mat(k, m, n):
        return jax.random.normal(k, (m, n), jnp.float32) / (m**0.5)

    layers = []
    for i in range(cfg.n_layers):
        b = ks[i * 8 : (i + 1) * 8]
        layers.append(
            dict(
                ln1=jnp.ones((D,)),
                wq=mat(b[0], D, cfg.n_q_heads * d),
                wk=mat(b[1], D, cfg.n_kv_heads * d),
                wv=mat(b[2], D, cfg.n_kv_heads * d),
                wo=mat(b[3], cfg.n_q_heads * d, D),
                ln2=jnp.ones((D,)),
                w1=mat(b[4], D, F),
                w3=mat(b[5], D, F),
                w2=mat(b[6], F, D),
            )
        )
    w_e = jax.random.normal(ks[-2], (cfg.vocab, cfg.d_model)) * 0.02
    w_u = jax.random.normal(ks[-1], (cfg.d_model, cfg.vocab)) / cfg.d_model**0.5
    return dict(layers=layers, w_e=w_e, lnf=jnp.ones((cfg.d_model,)), w_u=w_u)


def forward_dense(tokens, weights, cfg: ModelConfig):
    """Full dense prefill forward over `tokens` [T]; returns logits [T, V]."""
    T = tokens.shape[0]
    pos = jnp.arange(T, dtype=jnp.int32)
    x = embed(tokens, weights["w_e"])
    for lw in weights["layers"]:
        q, k, v = qkv(x, lw["ln1"], lw["wq"], lw["wk"], lw["wv"], pos, cfg)
        a = ref_k.dense_prefill(q, k, v)
        x = post(x, a, lw["wo"], lw["ln2"], lw["w1"], lw["w3"], lw["w2"])
    return logits(x, weights["lnf"], weights["w_u"])


def decode_step_dense(token, pos, kv_cache, weights, cfg: ModelConfig):
    """One dense decode step with a python-side KV cache (tests only).

    kv_cache: list of (K [n_kv, L, d], V [n_kv, L, d]) mutable buffers;
    pos: int current position.  Returns (logits [V], updated cache).
    """
    x = embed(jnp.array([token]), weights["w_e"])
    new_cache = []
    for lw, (K, V) in zip(weights["layers"], kv_cache):
        q, k1, v1 = qkv(
            x, lw["ln1"], lw["wq"], lw["wk"], lw["wv"],
            jnp.array([pos], jnp.int32), cfg,
        )
        K = K.at[:, pos, :].set(k1[:, 0, :])
        V = V.at[:, pos, :].set(v1[:, 0, :])
        new_cache.append((K, V))
        a = ref_k.dense_decode(q[:, 0, :], K, V, pos + 1)
        x = post(x, a[:, None, :], lw["wo"], lw["ln2"], lw["w1"], lw["w3"], lw["w2"])
    return logits(x, weights["lnf"], weights["w_u"])[0], new_cache
