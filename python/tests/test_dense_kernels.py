"""Pallas dense attention kernels vs the pure-jnp oracle (ref.py)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, ref
from .conftest import make_qkv

TOL = dict(rtol=2e-5, atol=2e-5)


class TestDenseDecode:
    @pytest.mark.parametrize("n_q,n_kv", [(8, 2), (8, 8), (4, 1), (16, 4)])
    def test_matches_ref_across_gqa_ratios(self, rng, n_q, n_kv):
        q, k, v = make_qkv(rng, n_q, n_kv, 64, 512)
        got = dense.dense_decode(q, k, v, jnp.array([512], jnp.int32))
        want = ref.dense_decode(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_length_masking_ignores_padded_keys(self, rng):
        q, k, v = make_qkv(rng, 8, 2, 64, 512)
        got = dense.dense_decode(q, k, v, jnp.array([300], jnp.int32))
        want = ref.dense_decode(q, k[:, :300], v[:, :300])
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_padding_values_are_irrelevant(self, rng):
        q, k, v = make_qkv(rng, 8, 2, 64, 512)
        k2 = np.array(k).copy()
        v2 = np.array(v).copy()
        k2[:, 300:] = 1e9  # garbage in the padded region
        v2[:, 300:] = -1e9
        a = dense.dense_decode(q, k, v, jnp.array([300], jnp.int32))
        b = dense.dense_decode(q, jnp.array(k2), jnp.array(v2), jnp.array([300], jnp.int32))
        np.testing.assert_allclose(np.array(a), np.array(b), **TOL)

    def test_output_is_convex_combination_of_values(self, rng):
        """Softmax weights are a convex combination: out within V's row hull."""
        q, k, v = make_qkv(rng, 4, 1, 32, 256)
        out = np.array(dense.dense_decode(q, k, v, jnp.array([256], jnp.int32)))
        vmin, vmax = np.array(v).min(axis=1)[0], np.array(v).max(axis=1)[0]
        assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()

    @settings(deadline=None, max_examples=12)
    @given(
        n_kv=st.sampled_from([1, 2, 4]),
        g=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([16, 32, 64, 128]),
        L=st.sampled_from([256, 512, 1024]),
        length_frac=st.floats(0.2, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, n_kv, g, d, L, length_frac, seed):
        rng = np.random.default_rng(seed)
        q, k, v = make_qkv(rng, n_kv * g, n_kv, d, L)
        length = max(1, int(L * length_frac))
        got = dense.dense_decode(q, k, v, jnp.array([length], jnp.int32))
        want = ref.dense_decode(q, k, v, length)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=5e-5, atol=5e-5)

    @pytest.mark.parametrize("dtype,tol", [(np.float32, 2e-5), (np.float16, 2e-2)])
    def test_dtype_sweep(self, rng, dtype, tol):
        q, k, v = make_qkv(rng, 8, 2, 64, 256, dtype=dtype)
        got = dense.dense_decode(jnp.array(q), jnp.array(k), jnp.array(v), jnp.array([256], jnp.int32))
        want = ref.dense_decode(
            jnp.array(q, jnp.float32), jnp.array(k, jnp.float32), jnp.array(v, jnp.float32)
        )
        np.testing.assert_allclose(
            np.array(got, np.float32), np.array(want), rtol=tol, atol=tol
        )


class TestDensePrefill:
    @pytest.mark.parametrize("T,L", [(128, 128), (128, 512), (256, 512), (512, 512)])
    def test_matches_ref(self, rng, T, L):
        q, k, v = make_qkv(rng, 8, 2, 64, L, T=T)
        got = dense.dense_prefill(q, k, v, jnp.array([L], jnp.int32), tile_q=128)
        want = ref.dense_prefill(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_causality_first_token_attends_only_to_prefix(self, rng):
        """With T == L, query 0 may only see key 0: out[:,0] == v[:,0] broadcast."""
        q, k, v = make_qkv(rng, 4, 2, 32, 256, T=256)
        out = np.array(dense.dense_prefill(q, k, v, jnp.array([256], jnp.int32), tile_q=128))
        v0 = np.array(v)[:, 0, :]  # [n_kv, d]
        want = np.repeat(v0, 2, axis=0)  # g=2 query heads per kv head
        np.testing.assert_allclose(out[:, 0, :], want, **TOL)

    def test_future_keys_are_invisible(self, rng):
        """Perturbing keys/values after position t must not change output t."""
        q, k, v = make_qkv(rng, 4, 2, 32, 256, T=256)
        base = np.array(dense.dense_prefill(q, k, v, jnp.array([256], jnp.int32), tile_q=128))
        k2, v2 = np.array(k).copy(), np.array(v).copy()
        k2[:, 200:], v2[:, 200:] = 7.0, -7.0
        pert = np.array(
            dense.dense_prefill(q, jnp.array(k2), jnp.array(v2), jnp.array([256], jnp.int32), tile_q=128)
        )
        np.testing.assert_allclose(base[:, :200], pert[:, :200], **TOL)

    def test_chunked_prefill_offset(self, rng):
        """T < L: queries are the last T positions (chunked prefill)."""
        q, k, v = make_qkv(rng, 8, 2, 64, 512, T=128)
        got = dense.dense_prefill(q, k, v, jnp.array([512], jnp.int32), tile_q=128)
        want = ref.dense_prefill(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    @settings(deadline=None, max_examples=8)
    @given(
        n_kv=st.sampled_from([1, 2]),
        g=st.sampled_from([2, 4]),
        d=st.sampled_from([32, 64]),
        nt=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_tile_sweep(self, n_kv, g, d, nt, seed):
        rng = np.random.default_rng(seed)
        T = 128 * nt
        q, k, v = make_qkv(rng, n_kv * g, n_kv, d, T, T=T)
        got = dense.dense_prefill(q, k, v, jnp.array([T], jnp.int32), tile_q=128)
        want = ref.dense_prefill(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=5e-5, atol=5e-5)
