"""Pooling strategies (Sec. 3.4): post-softmax must beat pre-softmax
recovery as tiles grow — the property behind Fig. 5."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from .conftest import make_qkv


def _recovery(pooled, per_query, kk):
    """Fraction of each query's oracle top-k mass captured by the pooled
    top-k index set (Eq. 3 with a == pooled selection)."""
    idx = np.array(ref.topk_indices(jnp.array(pooled), kk))
    p = np.array(per_query)
    got = np.take_along_axis(p, np.broadcast_to(idx[:, None, :], p.shape[:-1] + (kk,)), -1).sum(-1)
    oracle = -np.sort(-p, axis=-1)[..., :kk].sum(-1)
    return (got / np.maximum(oracle, 1e-12)).mean()


class TestPooling:
    def test_post_softmax_rows_are_distributions(self, rng):
        q, k, _ = make_qkv(rng, 8, 2, 64, 512)
        pooled = np.array(ref.pool_post_softmax_decode(q, k))
        assert pooled.shape == (2, 512)
        np.testing.assert_allclose(pooled.sum(-1), 1.0, rtol=1e-5)
        assert (pooled >= 0).all()

    def test_gqa_group_of_one_pooling_is_identity(self, rng):
        q, k, _ = make_qkv(rng, 2, 2, 64, 256)  # g == 1
        pooled = np.array(ref.pool_post_softmax_decode(q, k))
        per_q = np.array(ref.decode_scores(q, k))
        np.testing.assert_allclose(pooled, per_q, rtol=1e-6)

    def test_post_beats_pre_softmax_at_decode(self, rng):
        """GQA pooling: post-softmax recovers more per-query top-k mass."""
        post_r, pre_r = [], []
        for seed in range(8):
            r = np.random.default_rng(seed)
            q, k, _ = make_qkv(r, 8, 1, 64, 512, kscale=0.7)  # tile of 8 queries
            per_q = np.array(ref.decode_scores(q, k)).reshape(1, 8, 512)
            post = np.array(ref.pool_post_softmax_decode(q, k))
            pre = np.array(ref.pool_pre_softmax_decode(q, k))
            post_r.append(_recovery(post, per_q, 64))
            pre_r.append(_recovery(pre, per_q, 64))
        assert np.mean(post_r) >= np.mean(pre_r)

    @pytest.mark.parametrize("tile", [4, 8, 16, 32])
    def test_prefill_tile_pooling_shapes(self, rng, tile):
        q, k, _ = make_qkv(rng, 8, 2, 32, 128, T=128)
        pooled = np.array(ref.pool_post_softmax_prefill(q, k, tile))
        assert pooled.shape == (2, 128 // tile, 128)
        # rows sum to 1 (each pooled row is a mean of distributions)
        np.testing.assert_allclose(pooled.sum(-1), 1.0, rtol=1e-5)

    def test_prefill_pooling_degrades_gracefully_with_tile(self, rng):
        """Recovery decreases (weakly) as tiles grow — but post-softmax at
        tile 128 still captures the bulk of per-query mass (Fig. 5 shape)."""
        q, k, _ = make_qkv(rng, 8, 2, 64, 512, T=512, kscale=0.5)
        per_q = np.array(ref.prefill_scores(q, k))  # [8, 512, 512]
        recs = {}
        for tile in (4, 32, 128):
            pooled = np.array(ref.pool_post_softmax_prefill(q, k, tile))
            nt = 512 // tile
            pq = per_q.reshape(2, 4, nt, tile, 512).transpose(0, 2, 1, 3, 4).reshape(
                2 * nt, 4 * tile, 512
            )
            recs[tile] = _recovery(pooled.reshape(2 * nt, 512), pq, 64)
        assert recs[4] >= recs[128] - 0.05  # small tiles no worse
        assert recs[128] > 0.55  # big tiles still useful
