"""AOT artifact generation: manifest integrity + HLO text well-formedness."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestKRule:
    def test_paper_k_rule(self):
        # k = min(max(0.1 L, 128), L)
        assert aot.k_rule(512) == 128
        assert aot.k_rule(1280) == 128
        assert aot.k_rule(2048) == 204
        assert aot.k_rule(100) == 100  # capped at L
        assert aot.k_rule(4096) == 409


class TestEntryPoints:
    def test_enumeration_is_complete(self):
        names = [n for n, *_ in aot.entry_points(aot.CFG)]
        assert len(names) == len(set(names))
        for L in aot.DECODE_L:
            for kind in ("dense", "anchor", "anchor0", "reuse"):
                assert f"attn_{kind}_decode_l{L}" in names
        for T in aot.PREFILL_T:
            for kind in ("dense", "anchor", "anchor0", "reuse"):
                assert f"attn_{kind}_prefill_t{T}" in names
        assert "logits_decode" in names and "embed_decode" in names

    def test_every_entry_point_lowers(self):
        """Each entry point must trace + lower to stablehlo without error."""
        for name, fn, specs, _ in aot.entry_points(aot.CFG):
            lowered = jax.jit(aot._tuple_fn(fn)).lower(*specs)
            assert lowered.compiler_ir("stablehlo") is not None, name

    def test_hlo_text_roundtrip_format(self):
        """The emitted text must be XLA HLO text (parseable header, ENTRY)."""
        name, fn, specs, _ = next(iter(aot.entry_points(aot.CFG)))
        text = aot.to_hlo_text(jax.jit(aot._tuple_fn(fn)).lower(*specs))
        assert text.startswith("HloModule")
        assert "ENTRY" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifact_files_exist(self, manifest):
        for name, meta in manifest["artifacts"].items():
            path = os.path.join(ART, meta["file"])
            assert os.path.exists(path), name
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), name

    def test_config_matches_current(self, manifest):
        from dataclasses import asdict

        assert manifest["config"] == asdict(aot.CFG)

    def test_attention_shapes_consistent(self, manifest):
        cfg = aot.CFG
        for L in manifest["buckets"]["decode_l"]:
            a = manifest["artifacts"][f"attn_reuse_decode_l{L}"]
            assert a["inputs"][0]["shape"] == [cfg.n_q_heads, cfg.d_head]
            assert a["inputs"][3]["shape"] == [cfg.n_kv_heads, aot.k_rule(L)]
            assert a["outputs"][0]["shape"] == [cfg.n_q_heads, cfg.d_head]
        for T in manifest["buckets"]["prefill_t"]:
            a = manifest["artifacts"][f"attn_anchor_prefill_t{T}"]
            nt = T // manifest["buckets"]["tile"]
            assert a["outputs"][1]["shape"] == [cfg.n_kv_heads, nt, aot.k_rule(T)]
