"""L2 model ops: RoPE/RMSNorm properties, prefill/decode consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(n_layers=3, d_model=64, n_q_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256)


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(CFG, seed=7)


class TestBlocks:
    def test_rmsnorm_unit_scale(self):
        x = jnp.array(np.random.default_rng(0).standard_normal((5, 64)), jnp.float32)
        y = np.array(M.rmsnorm(x, jnp.ones((64,))))
        rms = np.sqrt((y**2).mean(-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_rope_preserves_norm(self):
        x = jnp.array(np.random.default_rng(1).standard_normal((2, 8, 32)), jnp.float32)
        y = M.rope(x, jnp.arange(8, dtype=jnp.int32), 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.array(y), axis=-1),
            np.linalg.norm(np.array(x), axis=-1),
            rtol=1e-5,
        )

    def test_rope_relative_position_invariance(self):
        """<rope(q,p1), rope(k,p2)> depends only on p1 - p2."""
        rng = np.random.default_rng(2)
        qv = jnp.array(rng.standard_normal((1, 1, 32)), jnp.float32)
        kv = jnp.array(rng.standard_normal((1, 1, 32)), jnp.float32)

        def dot(p1, p2):
            a = M.rope(qv, jnp.array([p1], jnp.int32), 10000.0)
            b = M.rope(kv, jnp.array([p2], jnp.int32), 10000.0)
            return float((a * b).sum())

        assert abs(dot(5, 3) - dot(105, 103)) < 1e-3
        assert abs(dot(17, 0) - dot(100, 83)) < 1e-3

    def test_rope_position_zero_is_identity(self):
        x = jnp.array(np.random.default_rng(3).standard_normal((1, 1, 32)), jnp.float32)
        y = M.rope(x, jnp.zeros((1,), jnp.int32), 10000.0)
        np.testing.assert_allclose(np.array(y), np.array(x), atol=1e-6)


class TestModelConsistency:
    def test_qkv_shapes(self, weights):
        lw = weights["layers"][0]
        x = jnp.array(np.random.default_rng(0).standard_normal((10, 64)), jnp.float32)
        q, k, v = M.qkv(x, lw["ln1"], lw["wq"], lw["wk"], lw["wv"],
                        jnp.arange(10, dtype=jnp.int32), CFG)
        assert q.shape == (4, 10, 16) and k.shape == (2, 10, 16) == v.shape

    def test_prefill_then_decode_matches_full_prefill(self, weights):
        """Prefill T tokens, then decode the next one step-by-step; logits
        must match a single prefill over T+2 tokens."""
        rng = np.random.default_rng(5)
        toks = rng.integers(0, 256, size=18).astype(np.int32)
        full = np.array(M.forward_dense(jnp.array(toks), weights, CFG))

        # incremental: prefill first 16, decode tokens 16, 17
        pre = np.array(M.forward_dense(jnp.array(toks[:16]), weights, CFG))
        np.testing.assert_allclose(pre[-1], full[15], rtol=2e-4, atol=2e-4)

        Lmax = 32
        cache = []
        x = M.embed(jnp.array(toks[:16]), weights["w_e"])
        pos = jnp.arange(16, dtype=jnp.int32)
        for lw in weights["layers"]:
            q, k, v = M.qkv(x, lw["ln1"], lw["wq"], lw["wk"], lw["wv"], pos, CFG)
            K = jnp.zeros((2, Lmax, 16)).at[:, :16].set(k)
            V = jnp.zeros((2, Lmax, 16)).at[:, :16].set(v)
            cache.append((K, V))
            a = ref.dense_prefill(q, k, v)
            x = M.post(x, a, lw["wo"], lw["ln2"], lw["w1"], lw["w3"], lw["w2"])

        lg16, cache = M.decode_step_dense(int(toks[16]), 16, cache, weights, CFG)
        np.testing.assert_allclose(np.array(lg16), full[16], rtol=2e-4, atol=2e-4)
        lg17, _ = M.decode_step_dense(int(toks[17]), 17, cache, weights, CFG)
        np.testing.assert_allclose(np.array(lg17), full[17], rtol=2e-4, atol=2e-4)

    def test_post_residual_passthrough(self, weights):
        """Zero attention output + zero mlp leaves x unchanged."""
        lw = {k: jnp.zeros_like(v) for k, v in weights["layers"][0].items()}
        x = jnp.array(np.random.default_rng(6).standard_normal((4, 64)), jnp.float32)
        a = jnp.zeros((4, 4, 16))
        y = M.post(x, a, lw["wo"], lw["ln2"], lw["w1"], lw["w3"], lw["w2"])
        np.testing.assert_allclose(np.array(y), np.array(x), atol=1e-6)
