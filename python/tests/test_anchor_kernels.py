"""Anchor multi-pass pipeline (Sec. 3.6) — pass-by-pass and end-to-end."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import anchor, dense, ref
from .conftest import make_qkv

TOL = dict(rtol=2e-5, atol=2e-5)


class TestDecodePasses:
    def test_pass1_scores(self, rng):
        q, k, _ = make_qkv(rng, 8, 2, 64, 512)
        s = np.array(anchor.decode_scores_pass(q, k, jnp.array([512], jnp.int32)))
        qg = np.array(q).reshape(2, 4, 64)
        want = np.einsum("hgd,hld->hgl", qg, np.array(k)) / 8.0
        np.testing.assert_allclose(s, want, **TOL)

    def test_pass1_masks_beyond_length(self, rng):
        q, k, _ = make_qkv(rng, 8, 2, 64, 512)
        s = np.array(anchor.decode_scores_pass(q, k, jnp.array([100], jnp.int32)))
        assert (s[:, :, 100:] <= -1e29).all()
        assert (s[:, :, :100] > -1e29).all()

    def test_pass2_pooled_softmax(self, rng):
        q, k, _ = make_qkv(rng, 8, 2, 64, 512)
        s = anchor.decode_scores_pass(q, k, jnp.array([512], jnp.int32))
        pooled = np.array(anchor.decode_pool_pass(s))
        want = np.array(ref.pool_post_softmax_decode(q, k))
        np.testing.assert_allclose(pooled, want, **TOL)
        # pooled rows are probability distributions
        np.testing.assert_allclose(pooled.sum(-1), 1.0, rtol=1e-5)

    def test_pass3_topk_matches_lax(self, rng):
        q, k, _ = make_qkv(rng, 8, 2, 64, 512)
        pooled = ref.pool_post_softmax_decode(q, k)
        idx = np.array(anchor.topk_pass(pooled, 64))
        want = np.array(ref.topk_indices(pooled, 64))
        np.testing.assert_array_equal(idx, want)

    def test_pass3_pads_zero_weight_slots(self, rng):
        """When length < k, the surplus slots must be -1 (masked)."""
        q, k, _ = make_qkv(rng, 8, 2, 64, 512)
        pooled = ref.pool_post_softmax_decode(q, k, 40)
        idx = np.array(anchor.topk_pass(pooled, 64))
        assert ((idx >= 0).sum(axis=1) == 40).all()
        assert (np.sort(idx[idx >= 0]) < 40).all()


class TestAnchorDecodeEndToEnd:
    @pytest.mark.parametrize("L,kk", [(512, 64), (512, 128), (1024, 128)])
    def test_matches_ref_pipeline(self, rng, L, kk):
        q, k, v = make_qkv(rng, 8, 2, 64, L)
        got_o, got_i = anchor.anchor_decode(q, k, v, jnp.array([L], jnp.int32), kk)
        want_o, want_i = ref.anchor_decode(q, k, v, kk)
        np.testing.assert_allclose(np.array(got_o), np.array(want_o), **TOL)
        np.testing.assert_array_equal(
            np.sort(np.array(got_i)), np.sort(np.array(want_i))
        )

    def test_anchor0_output_is_dense(self, rng):
        """Layer-0 anchors return the *dense* output (Sec. 3.1)."""
        q, k, v = make_qkv(rng, 8, 2, 64, 512)
        out, idx = anchor.anchor0_decode(q, k, v, jnp.array([512], jnp.int32), 64)
        want = ref.dense_decode(q, k, v)
        np.testing.assert_allclose(np.array(out), np.array(want), **TOL)
        assert np.array(idx).shape == (2, 64)

    def test_indices_capture_dominant_mass(self, rng):
        """With peaked scores, the selected 25% of keys must dominate the
        pooled mass (the intrinsic-sparsity premise of Sec. 3.1)."""
        q, k, v = make_qkv(rng, 8, 2, 64, 512, kscale=3.0)
        _, idx = anchor.anchor_decode(q, k, v, jnp.array([512], jnp.int32), 128)
        pooled = np.array(ref.pool_post_softmax_decode(q, k))
        mass = np.take_along_axis(pooled, np.array(idx), axis=1).sum(axis=1)
        assert (mass > 0.9).all()

    @settings(deadline=None, max_examples=8)
    @given(
        n_kv=st.sampled_from([1, 2]),
        g=st.sampled_from([2, 4]),
        L=st.sampled_from([256, 512]),
        kk=st.sampled_from([32, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n_kv, g, L, kk, seed):
        rng = np.random.default_rng(seed)
        q, k, v = make_qkv(rng, n_kv * g, n_kv, 32, L)
        got_o, got_i = anchor.anchor_decode(q, k, v, jnp.array([L], jnp.int32), kk)
        want_o, want_i = ref.anchor_decode(q, k, v, kk)
        np.testing.assert_allclose(
            np.array(got_o), np.array(want_o), rtol=5e-5, atol=5e-5
        )
        np.testing.assert_array_equal(
            np.sort(np.array(got_i)), np.sort(np.array(want_i))
        )


class TestAnchorPrefill:
    def test_pass1_stats_match_dense_softmax(self, rng):
        q, k, _ = make_qkv(rng, 8, 2, 64, 256, T=256)
        m, l = anchor.prefill_stats_pass(q, k, jnp.array([256], jnp.int32), 128)
        # recompute row max / sum-exp directly and compare
        s = np.einsum(
            "hgtd,hld->hgtl",
            np.array(q).reshape(2, 4, 256, 64),
            np.array(k),
        ).reshape(8, 256, 256) / 8.0
        causal = np.tril(np.ones((256, 256), bool))
        s = np.where(causal[None], s, -1e30)
        np.testing.assert_allclose(np.array(m), s.max(-1), **TOL)
        np.testing.assert_allclose(
            np.array(l), np.exp(s - s.max(-1, keepdims=True)).sum(-1), rtol=1e-4, atol=1e-4
        )

    def test_pass2_pooled_matches_ref(self, rng):
        q, k, _ = make_qkv(rng, 8, 2, 64, 256, T=256)
        ln = jnp.array([256], jnp.int32)
        m, l = anchor.prefill_stats_pass(q, k, ln, 128)
        pooled = np.array(anchor.prefill_pool_pass(q, k, m, l, ln, 128))
        want = np.array(ref.pool_post_softmax_prefill(q, k, 128))
        np.testing.assert_allclose(pooled, want, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("T,L", [(128, 128), (256, 256), (128, 512)])
    def test_end_to_end_matches_ref(self, rng, T, L):
        q, k, v = make_qkv(rng, 8, 2, 64, L, T=T)
        got_o, got_i = anchor.anchor_prefill(q, k, v, jnp.array([L], jnp.int32), 64, 128)
        want_o, want_i = ref.anchor_prefill(q, k, v, 64, 128)
        np.testing.assert_allclose(np.array(got_o), np.array(want_o), rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(
            np.sort(np.array(got_i), -1), np.sort(np.array(want_i), -1)
        )

    def test_anchor0_prefill_output_is_dense(self, rng):
        q, k, v = make_qkv(rng, 8, 2, 64, 256, T=256)
        out, idx = anchor.anchor0_prefill(q, k, v, jnp.array([256], jnp.int32), 64, 128)
        want = ref.dense_prefill(q, k, v)
        np.testing.assert_allclose(np.array(out), np.array(want), **TOL)
        assert np.array(idx).shape == (2, 2, 64)
