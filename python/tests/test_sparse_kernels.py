"""Reuse (sparse Top-k) kernels + head remapping vs the oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, reuse
from .conftest import make_qkv

TOL = dict(rtol=2e-5, atol=2e-5)


def _oracle_idx(q, k, kk, length=None):
    pooled = ref.pool_post_softmax_decode(q, k, length)
    return ref.topk_indices(pooled, kk)


class TestReuseDecode:
    def test_matches_ref_on_oracle_indices(self, rng):
        q, k, v = make_qkv(rng, 8, 2, 64, 512)
        idx = _oracle_idx(q, k, 64)
        got = reuse.reuse_decode(q, k, v, idx)
        want = ref.sparse_decode(q, k, v, idx)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_full_index_set_equals_dense(self, rng):
        """k == L: sparse attention over all keys must equal dense."""
        q, k, v = make_qkv(rng, 8, 2, 64, 256)
        idx = jnp.tile(jnp.arange(256, dtype=jnp.int32)[None], (2, 1))
        got = reuse.reuse_decode(q, k, v, idx)
        want = ref.dense_decode(q, k, v)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_negative_indices_are_masked(self, rng):
        """Padded (-1) slots must not contribute, whatever row 0 contains."""
        q, k, v = make_qkv(rng, 8, 2, 64, 256)
        idx = np.array(_oracle_idx(q, k, 64))
        idx[:, 32:] = -1
        a = reuse.reuse_decode(q, k, v, jnp.array(idx))
        b = ref.sparse_decode(q, k, v, jnp.array(idx[:, :32]))
        np.testing.assert_allclose(np.array(a), np.array(b), **TOL)

    def test_index_order_is_irrelevant(self, rng):
        q, k, v = make_qkv(rng, 8, 2, 64, 256)
        idx = np.array(_oracle_idx(q, k, 64))
        perm = np.random.default_rng(0).permutation(64)
        a = reuse.reuse_decode(q, k, v, jnp.array(idx))
        b = reuse.reuse_decode(q, k, v, jnp.array(idx[:, perm]))
        np.testing.assert_allclose(np.array(a), np.array(b), **TOL)

    def test_high_topk_approximates_dense(self, rng):
        """With concentrated scores, top-25% attention ~= dense (Sec. 3.1)."""
        q, k, v = make_qkv(rng, 8, 2, 64, 512, kscale=3.0)
        idx = _oracle_idx(q, k, 128)
        sparse = np.array(reuse.reuse_decode(q, k, v, idx))
        den = np.array(ref.dense_decode(q, k, v))
        cos = (sparse * den).sum() / (np.linalg.norm(sparse) * np.linalg.norm(den))
        assert cos > 0.98

    @settings(deadline=None, max_examples=10)
    @given(
        n_kv=st.sampled_from([1, 2, 4]),
        g=st.sampled_from([1, 4]),
        d=st.sampled_from([32, 64]),
        L=st.sampled_from([256, 512]),
        kk=st.sampled_from([16, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, n_kv, g, d, L, kk, seed):
        rng = np.random.default_rng(seed)
        q, k, v = make_qkv(rng, n_kv * g, n_kv, d, L)
        idx = _oracle_idx(q, k, kk)
        got = reuse.reuse_decode(q, k, v, idx)
        want = ref.sparse_decode(q, k, v, idx)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=5e-5, atol=5e-5)


class TestReusePrefill:
    @pytest.mark.parametrize("T,L,tile", [(128, 128, 128), (256, 256, 128), (128, 512, 128)])
    def test_matches_ref(self, rng, T, L, tile):
        q, k, v = make_qkv(rng, 8, 2, 64, L, T=T)
        pooled = ref.pool_post_softmax_prefill(q, k, tile)
        idx = ref.topk_indices(pooled, 64)
        got = reuse.reuse_prefill(q, k, v, idx, tile)
        want = ref.sparse_prefill(q, k, v, idx, tile)
        np.testing.assert_allclose(np.array(got), np.array(want), **TOL)

    def test_causal_masking_within_tile(self, rng):
        """Indices past a query's position are masked even when shared
        tile-wide (the rolling Top-k of Sec. 4.1)."""
        q, k, v = make_qkv(rng, 4, 2, 32, 128, T=128)
        # index set deliberately includes future positions for early queries
        idx = jnp.tile(jnp.arange(0, 128, 2, dtype=jnp.int32)[None, None], (2, 1, 1))
        got = np.array(reuse.reuse_prefill(q, k, v, idx, 128))
        want = np.array(ref.sparse_prefill(q, k, v, idx, 128))
        np.testing.assert_allclose(got, want, **TOL)
        # query at position 0: only key 0 is visible -> output == v[:, 0]
        v0 = np.repeat(np.array(v)[:, 0, :], 2, axis=0)
        np.testing.assert_allclose(got[:, 0, :], v0, **TOL)

    @settings(deadline=None, max_examples=6)
    @given(
        g=st.sampled_from([2, 4]),
        nt=st.integers(1, 3),
        kk=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, g, nt, kk, seed):
        rng = np.random.default_rng(seed)
        T = 128 * nt
        q, k, v = make_qkv(rng, 2 * g, 2, 32, T, T=T)
        pooled = ref.pool_post_softmax_prefill(q, k, 128)
        idx = ref.topk_indices(pooled, kk)
        got = reuse.reuse_prefill(q, k, v, idx, 128)
        want = ref.sparse_prefill(q, k, v, idx, 128)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=5e-5, atol=5e-5)


class TestHeadRemapping:
    def test_identity_map_is_noop(self, rng):
        q, k, _ = make_qkv(rng, 8, 4, 32, 256)
        idx = _oracle_idx(q, k, 32)
        got = ref.remap_indices(idx, jnp.arange(4, dtype=jnp.int32))
        np.testing.assert_array_equal(np.array(got), np.array(idx))

    def test_many_to_one_mapping(self, rng):
        q, k, _ = make_qkv(rng, 8, 4, 32, 256)
        idx = np.array(_oracle_idx(q, k, 32))
        got = np.array(ref.remap_indices(jnp.array(idx), jnp.array([2, 2, 0, 1])))
        np.testing.assert_array_equal(got[0], idx[2])
        np.testing.assert_array_equal(got[1], idx[2])
        np.testing.assert_array_equal(got[2], idx[0])
        np.testing.assert_array_equal(got[3], idx[1])
