import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


def make_qkv(rng, n_q, n_kv, d, L, T=None, dtype=np.float32, kscale=0.3):
    """Random attention inputs; kscale keeps score magnitudes realistic."""
    if T is None:
        q = rng.standard_normal((n_q, d)).astype(dtype)
    else:
        q = rng.standard_normal((n_q, T, d)).astype(dtype)
    k = (rng.standard_normal((n_kv, L, d)) * kscale).astype(dtype)
    v = rng.standard_normal((n_kv, L, d)).astype(dtype)
    return q, k, v
